//! Wire protocol: length-prefixed binary frames over TCP.
//!
//! Frame layout: `u32 LE payload length | u8 tag | payload`. Matrices are
//! `u32 rows | u32 cols | rows*cols f64 LE`. Strings are `u32 len | utf8`.
//! The protocol carries only leader-side-small state — partials, rotation
//! matrices, paths — never row data (see module docs in [`super`]).

use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::obs::trace::TraceCtx;
use std::io::{Read, Write};

/// Protocol version — bumped on any frame change.
/// v3: chunk-grained scheduling — `Phase` is a per-pass setup broadcast
/// (operand/means shipped once, not per chunk) tagged with a phase id and
/// the run's fixed `chunk_total` plus `shard_epoch`; `Assign` hands out one
/// chunk; workers ack per chunk with `ChunkDone`/`ChunkFailed` and emit
/// liveness `Heartbeat`s from a background thread.
/// v4: the format byte gains sparse input codes (libsvm / sparse-CSV /
/// csr) — frame layout unchanged, but a v3 worker cannot decode them.
/// v5: observability — `Phase` and `Assign` carry a 16-byte trace context
/// (trace id + parent span id, zeros when tracing is off) and `ChunkDone`
/// returns the worker's decode/compute/encode split in microseconds, so
/// the leader can emit one merged timeline attributing every chunk.
/// v6: distributed reduce — `Hello` gains a capability bitmap (absent on
/// v5 frames → 0), `Phase` appends a hold flag + band height (ignored by
/// v5 workers: frames are length-delimited and trailing bytes are legal),
/// and five reduce frames drive leader-relayed pairwise merge rounds:
/// `RMerge`/`RFetch`/`RWriteV` leader→worker, `ReducePart`/`ReduceDone`/
/// `ReduceFailed` worker→leader. Reduce-frame matrices are
/// self-describing raw-or-XOR-delta coded ([`crate::io::codec`]); the
/// leader only sends coded bytes to workers advertising [`CAP_CODEC`].
pub const VERSION: u32 = 6;

/// Oldest worker protocol version the leader still admits. v5 workers
/// can't hold reduce leaves (no [`CAP_HOLD`]), so their partials ride
/// `ChunkDone` as before and the leader merges on their behalf.
pub const MIN_VERSION: u32 = 5;

/// Capability bit: the worker holds chunk partials in memory after
/// `ChunkDone` and participates in merge rounds (`RMerge`/`RFetch`/
/// `RWriteV`).
pub const CAP_HOLD: u64 = 1;

/// Capability bit: the worker decodes XOR-delta coded matrices, so the
/// leader may send `enc = 1` payloads downstream. (Upstream the leader
/// always accepts both encodings — they're self-describing.)
pub const CAP_CODEC: u64 = 2;

/// Sentinel for `RMerge`'s `left_held`/`right_held`: this operand is not
/// a held leaf — it arrives on the wire in `src`.
pub const HOLD_NONE: u32 = u32::MAX;

/// Maximum accepted frame payload (64 MiB — a 2896² f64 partial; anything
/// larger indicates a protocol error, not a legitimate partial).
pub const MAX_FRAME: u32 = 64 << 20;

/// The phase a worker is asked to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseKind {
    /// Pass 1: fused `Y = A Ω` + partial `YᵀY`; Y shard to shared fs.
    ProjectGram = 1,
    /// Pass 2: `U0 = Y M` + partial `Aᵀ U0`; U0 shard to shared fs.
    UrecoverTmul = 2,
    /// Pass 3: rotate `U = U0 P`; U shard to shared fs.
    RotateU = 3,
    /// Standalone `AᵀA` partial (the `ata` subcommand, distributed; also
    /// pass 1 of the exact-Gram route).
    Ata = 4,
    /// Pass 0 (PCA mode): per-column sums partial (1 x n).
    ColStats = 5,
    /// Exact-Gram pass 2: `U = A M` straight to U shards.
    Mult = 6,
}

impl PhaseKind {
    /// Short stable name used in trace span labels and metric labels.
    pub fn name(self) -> &'static str {
        match self {
            PhaseKind::ProjectGram => "projectgram",
            PhaseKind::UrecoverTmul => "urecover",
            PhaseKind::RotateU => "rotate",
            PhaseKind::Ata => "ata",
            PhaseKind::ColStats => "colstats",
            PhaseKind::Mult => "mult",
        }
    }

    pub fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            1 => PhaseKind::ProjectGram,
            2 => PhaseKind::UrecoverTmul,
            3 => PhaseKind::RotateU,
            4 => PhaseKind::Ata,
            5 => PhaseKind::ColStats,
            6 => PhaseKind::Mult,
            other => return Err(Error::parse(format!("unknown phase kind {other}"))),
        })
    }
}

fn format_to_u8(f: InputFormat) -> u8 {
    match f {
        InputFormat::Csv => 0,
        InputFormat::Bin => 1,
        InputFormat::Libsvm => 2,
        InputFormat::SparseCsv => 3,
        InputFormat::Csr => 4,
    }
}

fn format_from_u8(v: u8) -> Result<InputFormat> {
    match v {
        0 => Ok(InputFormat::Csv),
        1 => Ok(InputFormat::Bin),
        2 => Ok(InputFormat::Libsvm),
        3 => Ok(InputFormat::SparseCsv),
        4 => Ok(InputFormat::Csr),
        other => Err(Error::parse(format!("unknown format code {other}"))),
    }
}

/// Leader -> worker messages.
#[derive(Debug)]
pub enum ToWorker {
    /// Per-pass setup, broadcast once to every worker (and replayed to
    /// late joiners): everything a chunk execution needs *except* the
    /// chunk index, which arrives per [`ToWorker::Assign`].
    Phase {
        /// Monotonic phase id; `Assign` and chunk acks quote it so stale
        /// frames from a previous pass are recognizable.
        id: u64,
        kind: PhaseKind,
        /// Shared input file (visible to the worker — paper's assumption).
        input_path: String,
        /// Parse format of the input file. Sent explicitly so a worker
        /// never re-guesses from the extension (parity with the local
        /// executor for format-explicit inputs).
        input_format: InputFormat,
        /// Shard/working directory on the shared filesystem.
        work_dir: String,
        /// The run's fixed chunk count: both sides recompute identical
        /// chunk geometry from `(index, chunk_total)` and the shared file.
        chunk_total: u32,
        /// Row-block size.
        block: u32,
        /// Sketch seed (ProjectGram regenerates Ω from this — virtual B
        /// across the cluster, the paper's §2.1).
        seed: u64,
        /// Sketch width k' (ProjectGram) / columns (others).
        kp: u32,
        /// Input column count n — sent so workers skip a `dims()` scan of
        /// the tall file on every phase.
        cols: u32,
        /// Format of the Y/U0/U shards the worker writes.
        shard_format: InputFormat,
        /// Shard-namespace epoch (power-iteration round) — see
        /// [`crate::svd::PassContext::shard_epoch`].
        shard_epoch: u32,
        /// Small shared operand: Ω override for power iterations (rows > 0),
        /// M for UrecoverTmul/Mult, P for RotateU, unused otherwise.
        operand: Matrix,
        /// Column means for PCA mode (1 x n; 0x0 = centering off).
        means: Matrix,
        /// Trace context of the leader's phase span
        /// ([`TraceCtx::NONE`] when the run isn't traced).
        trace: TraceCtx,
        /// Tree-reduce hold mode (v6): `true` asks [`CAP_HOLD`] workers to
        /// keep their chunk partial in memory (band-split at `band_rows`)
        /// and ship an empty `ChunkDone` partial; merge rounds follow.
        hold: bool,
        /// Row-band height for held partials (0 = one band). Both sides
        /// derive identical band splits from `(partial rows, band_rows)`.
        band_rows: u64,
    },
    /// Run chunk `chunk` of phase `phase` (the current `Phase` setup).
    /// `trace` is the per-assignment span context (parent = phase span).
    Assign { phase: u64, chunk: u32, trace: TraceCtx },
    /// One pairwise merge step of the tree schedule
    /// ([`crate::svd::reduce::merge_rounds`]): combine exactly two
    /// operands of band `band` and hold the sum at key `(dst_lo, band)`.
    /// An operand is either one of this worker's held leaves (named
    /// explicitly by its span-lo key — never inferred, so stale leaves
    /// from lost speculative executions are untouchable) or the wire
    /// matrix `src` when the name is [`HOLD_NONE`].
    RMerge {
        phase: u64,
        dst_lo: u32,
        band: u32,
        left_held: u32,
        right_held: u32,
        src: Matrix,
    },
    /// Ship reduce state of held key `(lo, band)` back to the leader:
    /// the raw partial (consuming the held entry) or its TSQR R factor
    /// (keeping the entry for a later [`ToWorker::RWriteV`]).
    RFetch { phase: u64, lo: u32, band: u32, what: FetchWhat },
    /// Finish the W reduction locally: multiply the held band `(lo, band)`
    /// by the completion's `M_v = P_k Σ_k⁻¹` and write the product as row
    /// shard `shard` of the staged `V` [`crate::io::writer::ShardSet`] —
    /// the leader never materializes the n-sized factor.
    RWriteV { phase: u64, lo: u32, band: u32, shard: u32, mv: Matrix },
    /// All phases done; worker may exit.
    Shutdown,
}

/// What [`ToWorker::RFetch`] asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FetchWhat {
    /// The held partial itself; the worker forgets it after sending.
    Partial = 0,
    /// Its `k'×k'` TSQR R factor; the held band is kept.
    RFactor = 1,
}

impl FetchWhat {
    fn from_u8(v: u8) -> Result<Self> {
        match v {
            0 => Ok(FetchWhat::Partial),
            1 => Ok(FetchWhat::RFactor),
            other => Err(Error::parse(format!("unknown fetch kind {other}"))),
        }
    }
}

/// Worker -> leader messages.
#[derive(Debug)]
pub enum ToLeader {
    /// Greeting with protocol version and capability bitmap
    /// ([`CAP_HOLD`] | [`CAP_CODEC`]; v5 frames carry no bitmap → 0).
    Hello { version: u32, caps: u64 },
    /// One chunk finished: rows streamed + the commutative partial
    /// (possibly 0x0 for phases that only write shards). The three `_us`
    /// fields are the worker's measured decode/compute/encode split.
    ChunkDone {
        phase: u64,
        chunk: u32,
        rows: u64,
        decode_us: u64,
        compute_us: u64,
        encode_us: u64,
        partial: Matrix,
    },
    /// One chunk failed worker-side; the leader decides (retry elsewhere
    /// or fail the pass). The worker stays up.
    ChunkFailed { phase: u64, chunk: u32, message: String },
    /// Periodic liveness signal from the worker's heartbeat thread (sent
    /// even while a chunk is executing).
    Heartbeat,
    /// Reply to [`ToWorker::RFetch`]: the requested reduce state.
    ReducePart { phase: u64, lo: u32, band: u32, matrix: Matrix },
    /// Ack for a completed [`ToWorker::RMerge`] / [`ToWorker::RWriteV`].
    ReduceDone { phase: u64, lo: u32, band: u32 },
    /// A reduce step failed worker-side (missing held operand, shard I/O
    /// error, ...). The leader restarts the phase attempt.
    ReduceFailed { phase: u64, lo: u32, band: u32, message: String },
}

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

fn write_frame(w: &mut impl Write, tag: u8, payload: &[u8]) -> Result<()> {
    if payload.len() as u64 > MAX_FRAME as u64 {
        return Err(Error::Other(format!("frame too large: {}", payload.len())));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&[tag])?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

fn read_frame(r: &mut impl Read) -> Result<(u8, Vec<u8>)> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4);
    if len > MAX_FRAME {
        return Err(Error::parse(format!("oversized frame: {len} bytes")));
    }
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    Ok((tag[0], payload))
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::parse("truncated frame".to_string()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| Error::parse("bad utf8".to_string()))
    }

    fn matrix(&mut self) -> Result<Matrix> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let need = rows
            .checked_mul(cols)
            .and_then(|n| n.checked_mul(8))
            .ok_or_else(|| Error::parse("matrix size overflow".to_string()))?;
        let bytes = self.take(need)?;
        let mut data = Vec::with_capacity(rows * cols);
        for c in bytes.chunks_exact(8) {
            data.push(f64::from_le_bytes(c.try_into().unwrap()));
        }
        Matrix::from_vec(rows, cols, data)
    }

    /// Self-describing raw-or-coded matrix (reduce frames only):
    /// `u32 rows | u32 cols | u8 enc | u32 len | payload`, where `enc = 0`
    /// is raw `f64` LE bytes and `enc = 1` is the XOR-delta stream of
    /// [`crate::io::codec`].
    fn coded_matrix(&mut self) -> Result<Matrix> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let count = rows
            .checked_mul(cols)
            .ok_or_else(|| Error::parse("matrix size overflow".to_string()))?;
        let enc = self.u8()?;
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        let data = match enc {
            0 => {
                if len != count * 8 {
                    return Err(Error::parse("raw matrix payload length mismatch".to_string()));
                }
                bytes
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            }
            1 => crate::io::codec::decode_f64s(bytes, count)?,
            other => return Err(Error::parse(format!("unknown matrix encoding {other}"))),
        };
        Matrix::from_vec(rows, cols, data)
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

fn put_string(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

fn put_matrix(buf: &mut Vec<u8>, m: &Matrix) {
    buf.extend_from_slice(&(m.rows() as u32).to_le_bytes());
    buf.extend_from_slice(&(m.cols() as u32).to_le_bytes());
    for &v in m.data() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_trace(buf: &mut Vec<u8>, t: &TraceCtx) {
    buf.extend_from_slice(&t.trace.to_le_bytes());
    buf.extend_from_slice(&t.span.to_le_bytes());
}

/// Counterpart of [`Cursor::coded_matrix`]. `coded = false` must remain
/// available even on v6 links: the leader only codes toward workers that
/// advertised [`CAP_CODEC`].
fn put_coded_matrix(buf: &mut Vec<u8>, m: &Matrix, coded: bool) {
    buf.extend_from_slice(&(m.rows() as u32).to_le_bytes());
    buf.extend_from_slice(&(m.cols() as u32).to_le_bytes());
    if coded {
        let payload = crate::io::codec::encode_f64s(m.data());
        buf.push(1);
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&payload);
    } else {
        buf.push(0);
        buf.extend_from_slice(&((m.data().len() * 8) as u32).to_le_bytes());
        for &v in m.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

impl Cursor<'_> {
    fn trace(&mut self) -> Result<TraceCtx> {
        Ok(TraceCtx { trace: self.u64()?, span: self.u64()? })
    }
}

// tags
const T_PHASE: u8 = 0x01;
const T_SHUTDOWN: u8 = 0x02;
const T_ASSIGN: u8 = 0x03;
const T_RMERGE: u8 = 0x06;
const T_RFETCH: u8 = 0x07;
const T_RWRITE_V: u8 = 0x08;
const T_HELLO: u8 = 0x10;
const T_CHUNK_DONE: u8 = 0x11;
const T_CHUNK_FAILED: u8 = 0x12;
const T_HEARTBEAT: u8 = 0x13;
const T_REDUCE_PART: u8 = 0x14;
const T_REDUCE_DONE: u8 = 0x15;
const T_REDUCE_FAILED: u8 = 0x16;

impl ToWorker {
    /// Write with no downstream capabilities assumed (matrices uncoded).
    pub fn write(&self, w: &mut impl Write) -> Result<()> {
        self.write_caps(w, 0)
    }

    /// Write toward a worker whose Hello advertised `caps`: reduce-frame
    /// matrices are XOR-delta coded iff the worker claims [`CAP_CODEC`].
    pub fn write_caps(&self, w: &mut impl Write, caps: u64) -> Result<()> {
        let coded = caps & CAP_CODEC != 0;
        match self {
            ToWorker::Phase {
                id,
                kind,
                input_path,
                input_format,
                work_dir,
                chunk_total,
                block,
                seed,
                kp,
                cols,
                shard_format,
                shard_epoch,
                operand,
                means,
                trace,
                hold,
                band_rows,
            } => {
                let mut buf = Vec::new();
                buf.extend_from_slice(&id.to_le_bytes());
                buf.push(*kind as u8);
                put_string(&mut buf, input_path);
                buf.push(format_to_u8(*input_format));
                put_string(&mut buf, work_dir);
                buf.extend_from_slice(&chunk_total.to_le_bytes());
                buf.extend_from_slice(&block.to_le_bytes());
                buf.extend_from_slice(&seed.to_le_bytes());
                buf.extend_from_slice(&kp.to_le_bytes());
                buf.extend_from_slice(&cols.to_le_bytes());
                buf.push(format_to_u8(*shard_format));
                buf.extend_from_slice(&shard_epoch.to_le_bytes());
                put_matrix(&mut buf, operand);
                put_matrix(&mut buf, means);
                put_trace(&mut buf, trace);
                // v6 fields ride behind the v5 payload; v5 readers stop
                // at the trace and never see them.
                buf.push(u8::from(*hold));
                buf.extend_from_slice(&band_rows.to_le_bytes());
                write_frame(w, T_PHASE, &buf)
            }
            ToWorker::Assign { phase, chunk, trace } => {
                let mut buf = Vec::new();
                buf.extend_from_slice(&phase.to_le_bytes());
                buf.extend_from_slice(&chunk.to_le_bytes());
                put_trace(&mut buf, trace);
                write_frame(w, T_ASSIGN, &buf)
            }
            ToWorker::RMerge { phase, dst_lo, band, left_held, right_held, src } => {
                let mut buf = Vec::new();
                buf.extend_from_slice(&phase.to_le_bytes());
                buf.extend_from_slice(&dst_lo.to_le_bytes());
                buf.extend_from_slice(&band.to_le_bytes());
                buf.extend_from_slice(&left_held.to_le_bytes());
                buf.extend_from_slice(&right_held.to_le_bytes());
                put_coded_matrix(&mut buf, src, coded);
                write_frame(w, T_RMERGE, &buf)
            }
            ToWorker::RFetch { phase, lo, band, what } => {
                let mut buf = Vec::new();
                buf.extend_from_slice(&phase.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&band.to_le_bytes());
                buf.push(*what as u8);
                write_frame(w, T_RFETCH, &buf)
            }
            ToWorker::RWriteV { phase, lo, band, shard, mv } => {
                let mut buf = Vec::new();
                buf.extend_from_slice(&phase.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&band.to_le_bytes());
                buf.extend_from_slice(&shard.to_le_bytes());
                put_coded_matrix(&mut buf, mv, coded);
                write_frame(w, T_RWRITE_V, &buf)
            }
            ToWorker::Shutdown => write_frame(w, T_SHUTDOWN, &[]),
        }
    }

    pub fn read(r: &mut impl Read) -> Result<Self> {
        let (tag, payload) = read_frame(r)?;
        let mut c = Cursor::new(&payload);
        match tag {
            T_PHASE => Ok(ToWorker::Phase {
                id: c.u64()?,
                kind: PhaseKind::from_u8(c.u8()?)?,
                input_path: c.string()?,
                input_format: format_from_u8(c.u8()?)?,
                work_dir: c.string()?,
                chunk_total: c.u32()?,
                block: c.u32()?,
                seed: c.u64()?,
                kp: c.u32()?,
                cols: c.u32()?,
                shard_format: format_from_u8(c.u8()?)?,
                shard_epoch: c.u32()?,
                operand: c.matrix()?,
                means: c.matrix()?,
                trace: c.trace()?,
                // Absent on frames from a v5-era leader → hold off.
                hold: if c.remaining() > 0 { c.u8()? != 0 } else { false },
                band_rows: if c.remaining() > 0 { c.u64()? } else { 0 },
            }),
            T_ASSIGN => {
                Ok(ToWorker::Assign { phase: c.u64()?, chunk: c.u32()?, trace: c.trace()? })
            }
            T_RMERGE => Ok(ToWorker::RMerge {
                phase: c.u64()?,
                dst_lo: c.u32()?,
                band: c.u32()?,
                left_held: c.u32()?,
                right_held: c.u32()?,
                src: c.coded_matrix()?,
            }),
            T_RFETCH => Ok(ToWorker::RFetch {
                phase: c.u64()?,
                lo: c.u32()?,
                band: c.u32()?,
                what: FetchWhat::from_u8(c.u8()?)?,
            }),
            T_RWRITE_V => Ok(ToWorker::RWriteV {
                phase: c.u64()?,
                lo: c.u32()?,
                band: c.u32()?,
                shard: c.u32()?,
                mv: c.coded_matrix()?,
            }),
            T_SHUTDOWN => Ok(ToWorker::Shutdown),
            other => Err(Error::parse(format!("unexpected leader frame {other:#x}"))),
        }
    }
}

impl ToLeader {
    pub fn write(&self, w: &mut impl Write) -> Result<()> {
        match self {
            ToLeader::Hello { version, caps } => {
                let mut buf = Vec::new();
                buf.extend_from_slice(&version.to_le_bytes());
                buf.extend_from_slice(&caps.to_le_bytes());
                write_frame(w, T_HELLO, &buf)
            }
            ToLeader::ChunkDone {
                phase,
                chunk,
                rows,
                decode_us,
                compute_us,
                encode_us,
                partial,
            } => {
                let mut buf = Vec::new();
                buf.extend_from_slice(&phase.to_le_bytes());
                buf.extend_from_slice(&chunk.to_le_bytes());
                buf.extend_from_slice(&rows.to_le_bytes());
                buf.extend_from_slice(&decode_us.to_le_bytes());
                buf.extend_from_slice(&compute_us.to_le_bytes());
                buf.extend_from_slice(&encode_us.to_le_bytes());
                put_matrix(&mut buf, partial);
                write_frame(w, T_CHUNK_DONE, &buf)
            }
            ToLeader::ChunkFailed { phase, chunk, message } => {
                let mut buf = Vec::new();
                buf.extend_from_slice(&phase.to_le_bytes());
                buf.extend_from_slice(&chunk.to_le_bytes());
                put_string(&mut buf, message);
                write_frame(w, T_CHUNK_FAILED, &buf)
            }
            ToLeader::Heartbeat => write_frame(w, T_HEARTBEAT, &[]),
            ToLeader::ReducePart { phase, lo, band, matrix } => {
                let mut buf = Vec::new();
                buf.extend_from_slice(&phase.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&band.to_le_bytes());
                // Upstream is always coded: a v6 worker knows its leader
                // is v6 (a v5 leader would have rejected its Hello).
                put_coded_matrix(&mut buf, matrix, true);
                write_frame(w, T_REDUCE_PART, &buf)
            }
            ToLeader::ReduceDone { phase, lo, band } => {
                let mut buf = Vec::new();
                buf.extend_from_slice(&phase.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&band.to_le_bytes());
                write_frame(w, T_REDUCE_DONE, &buf)
            }
            ToLeader::ReduceFailed { phase, lo, band, message } => {
                let mut buf = Vec::new();
                buf.extend_from_slice(&phase.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&band.to_le_bytes());
                put_string(&mut buf, message);
                write_frame(w, T_REDUCE_FAILED, &buf)
            }
        }
    }

    pub fn read(r: &mut impl Read) -> Result<Self> {
        let (tag, payload) = read_frame(r)?;
        let mut c = Cursor::new(&payload);
        match tag {
            T_HELLO => Ok(ToLeader::Hello {
                version: c.u32()?,
                // v5 Hellos end after the version word → no capabilities.
                caps: if c.remaining() > 0 { c.u64()? } else { 0 },
            }),
            T_CHUNK_DONE => Ok(ToLeader::ChunkDone {
                phase: c.u64()?,
                chunk: c.u32()?,
                rows: c.u64()?,
                decode_us: c.u64()?,
                compute_us: c.u64()?,
                encode_us: c.u64()?,
                partial: c.matrix()?,
            }),
            T_CHUNK_FAILED => Ok(ToLeader::ChunkFailed {
                phase: c.u64()?,
                chunk: c.u32()?,
                message: c.string()?,
            }),
            T_HEARTBEAT => Ok(ToLeader::Heartbeat),
            T_REDUCE_PART => Ok(ToLeader::ReducePart {
                phase: c.u64()?,
                lo: c.u32()?,
                band: c.u32()?,
                matrix: c.coded_matrix()?,
            }),
            T_REDUCE_DONE => {
                Ok(ToLeader::ReduceDone { phase: c.u64()?, lo: c.u32()?, band: c.u32()? })
            }
            T_REDUCE_FAILED => Ok(ToLeader::ReduceFailed {
                phase: c.u64()?,
                lo: c.u32()?,
                band: c.u32()?,
                message: c.string()?,
            }),
            other => Err(Error::parse(format!("unexpected worker frame {other:#x}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_worker(msg: &ToWorker) -> ToWorker {
        let mut buf = Vec::new();
        msg.write(&mut buf).unwrap();
        ToWorker::read(&mut buf.as_slice()).unwrap()
    }

    fn roundtrip_leader(msg: &ToLeader) -> ToLeader {
        let mut buf = Vec::new();
        msg.write(&mut buf).unwrap();
        ToLeader::read(&mut buf.as_slice()).unwrap()
    }

    #[test]
    fn phase_roundtrip() {
        let m = Matrix::from_fn(3, 2, |i, j| (i * 2 + j) as f64 * 0.5);
        let mu = Matrix::from_fn(1, 4, |_, j| j as f64 + 0.5);
        let msg = ToWorker::Phase {
            id: 41,
            kind: PhaseKind::ProjectGram,
            input_path: "/data/a.csv".into(),
            input_format: InputFormat::Csv,
            work_dir: "/tmp/w".into(),
            chunk_total: 8,
            block: 256,
            seed: 0xDEAD_BEEF,
            kp: 32,
            cols: 4,
            shard_format: InputFormat::Csv,
            shard_epoch: 2,
            operand: m.clone(),
            means: mu.clone(),
            trace: TraceCtx { trace: 0xAB, span: 0xCD },
            hold: true,
            band_rows: 4096,
        };
        match roundtrip_worker(&msg) {
            ToWorker::Phase {
                id,
                kind,
                input_path,
                chunk_total,
                seed,
                kp,
                shard_format,
                shard_epoch,
                operand,
                means,
                trace,
                hold,
                band_rows,
                ..
            } => {
                assert_eq!(id, 41);
                assert_eq!(kind, PhaseKind::ProjectGram);
                assert_eq!(input_path, "/data/a.csv");
                assert_eq!(chunk_total, 8);
                assert_eq!(seed, 0xDEAD_BEEF);
                assert_eq!(kp, 32);
                assert_eq!(shard_format, InputFormat::Csv);
                assert_eq!(shard_epoch, 2);
                assert_eq!(operand.max_abs_diff(&m), 0.0);
                assert_eq!(means.max_abs_diff(&mu), 0.0);
                assert_eq!(trace, TraceCtx { trace: 0xAB, span: 0xCD });
                assert!(hold);
                assert_eq!(band_rows, 4096);
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn new_phase_kinds_roundtrip() {
        for kind in [PhaseKind::ColStats, PhaseKind::Mult] {
            let msg = ToWorker::Phase {
                id: 1,
                kind,
                input_path: "/data/a.bin".into(),
                input_format: InputFormat::Bin,
                work_dir: "/tmp/w".into(),
                chunk_total: 1,
                block: 64,
                seed: 1,
                kp: 4,
                cols: 4,
                shard_format: InputFormat::Bin,
                shard_epoch: 0,
                operand: Matrix::zeros(0, 0),
                means: Matrix::zeros(0, 0),
                trace: TraceCtx::NONE,
                hold: false,
                band_rows: 0,
            };
            match roundtrip_worker(&msg) {
                ToWorker::Phase { kind: got, trace, hold, .. } => {
                    assert_eq!(got, kind);
                    assert!(trace.is_none());
                    assert!(!hold);
                }
                other => panic!("wrong message: {other:?}"),
            }
        }
        assert!(PhaseKind::from_u8(7).is_err());
    }

    #[test]
    fn sparse_input_formats_roundtrip() {
        for fmt in [InputFormat::Libsvm, InputFormat::SparseCsv, InputFormat::Csr] {
            let msg = ToWorker::Phase {
                id: 2,
                kind: PhaseKind::ProjectGram,
                input_path: "/data/a.libsvm".into(),
                input_format: fmt,
                work_dir: "/tmp/w".into(),
                chunk_total: 4,
                block: 64,
                seed: 9,
                kp: 8,
                cols: 16,
                shard_format: InputFormat::Bin,
                shard_epoch: 0,
                operand: Matrix::zeros(0, 0),
                means: Matrix::zeros(0, 0),
                trace: TraceCtx::NONE,
                hold: false,
                band_rows: 0,
            };
            match roundtrip_worker(&msg) {
                ToWorker::Phase { input_format, shard_format, .. } => {
                    assert_eq!(input_format, fmt);
                    assert_eq!(shard_format, InputFormat::Bin);
                }
                other => panic!("wrong message: {other:?}"),
            }
        }
        assert!(format_from_u8(99).is_err());
    }

    #[test]
    fn assign_roundtrip() {
        let ctx = TraceCtx { trace: 0x1122_3344_5566_7788, span: 0x99AA };
        match roundtrip_worker(&ToWorker::Assign { phase: 7, chunk: 12, trace: ctx }) {
            ToWorker::Assign { phase, chunk, trace } => {
                assert_eq!(phase, 7);
                assert_eq!(chunk, 12);
                assert_eq!(trace, ctx);
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn shutdown_hello_heartbeat_roundtrip() {
        assert!(matches!(roundtrip_worker(&ToWorker::Shutdown), ToWorker::Shutdown));
        match roundtrip_leader(&ToLeader::Hello { version: VERSION, caps: CAP_HOLD | CAP_CODEC }) {
            ToLeader::Hello { version, caps } => {
                assert_eq!(version, VERSION);
                assert_eq!(caps, CAP_HOLD | CAP_CODEC);
            }
            other => panic!("wrong message: {other:?}"),
        }
        assert!(matches!(roundtrip_leader(&ToLeader::Heartbeat), ToLeader::Heartbeat));
    }

    #[test]
    fn short_v5_hello_decodes_with_zero_caps() {
        // A v5 worker's Hello is just the 4-byte version word.
        let mut buf = Vec::new();
        buf.extend_from_slice(&4u32.to_le_bytes()); // payload len
        buf.push(T_HELLO);
        buf.extend_from_slice(&5u32.to_le_bytes());
        match ToLeader::read(&mut buf.as_slice()).unwrap() {
            ToLeader::Hello { version, caps } => {
                assert_eq!(version, 5);
                assert_eq!(caps, 0);
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn v5_length_phase_decodes_with_hold_off() {
        // Serialize a v6 Phase, strip the 9 appended bytes (hold u8 +
        // band_rows u64) to reconstruct the exact v5 payload, and check
        // the v6 reader defaults the new fields.
        let msg = ToWorker::Phase {
            id: 3,
            kind: PhaseKind::Ata,
            input_path: "/d/a.csv".into(),
            input_format: InputFormat::Csv,
            work_dir: "/tmp/w".into(),
            chunk_total: 2,
            block: 64,
            seed: 7,
            kp: 4,
            cols: 4,
            shard_format: InputFormat::Csv,
            shard_epoch: 0,
            operand: Matrix::zeros(0, 0),
            means: Matrix::zeros(0, 0),
            trace: TraceCtx::NONE,
            hold: true,
            band_rows: 77,
        };
        let mut buf = Vec::new();
        msg.write(&mut buf).unwrap();
        let old_len = u32::from_le_bytes(buf[..4].try_into().unwrap()) - 9;
        buf.truncate(buf.len() - 9);
        buf[..4].copy_from_slice(&old_len.to_le_bytes());
        match ToWorker::read(&mut buf.as_slice()).unwrap() {
            ToWorker::Phase { hold, band_rows, id, .. } => {
                assert_eq!(id, 3);
                assert!(!hold);
                assert_eq!(band_rows, 0);
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn reduce_frames_roundtrip() {
        let m = Matrix::from_fn(5, 3, |i, j| (i as f64) * 1.5 - j as f64);
        match roundtrip_worker(&ToWorker::RMerge {
            phase: 9,
            dst_lo: 0,
            band: 2,
            left_held: 0,
            right_held: HOLD_NONE,
            src: m.clone(),
        }) {
            ToWorker::RMerge { phase, dst_lo, band, left_held, right_held, src } => {
                assert_eq!((phase, dst_lo, band), (9, 0, 2));
                assert_eq!((left_held, right_held), (0, HOLD_NONE));
                assert_eq!(src.max_abs_diff(&m), 0.0);
            }
            other => panic!("wrong message: {other:?}"),
        }
        match roundtrip_worker(&ToWorker::RFetch {
            phase: 9,
            lo: 4,
            band: 0,
            what: FetchWhat::RFactor,
        }) {
            ToWorker::RFetch { lo, what, .. } => {
                assert_eq!(lo, 4);
                assert_eq!(what, FetchWhat::RFactor);
            }
            other => panic!("wrong message: {other:?}"),
        }
        match roundtrip_worker(&ToWorker::RWriteV {
            phase: 9,
            lo: 0,
            band: 1,
            shard: 1,
            mv: m.clone(),
        }) {
            ToWorker::RWriteV { shard, mv, .. } => {
                assert_eq!(shard, 1);
                assert_eq!(mv.max_abs_diff(&m), 0.0);
            }
            other => panic!("wrong message: {other:?}"),
        }
        match roundtrip_leader(&ToLeader::ReducePart { phase: 9, lo: 2, band: 1, matrix: m.clone() })
        {
            ToLeader::ReducePart { lo, band, matrix, .. } => {
                assert_eq!((lo, band), (2, 1));
                assert_eq!(matrix.max_abs_diff(&m), 0.0);
            }
            other => panic!("wrong message: {other:?}"),
        }
        assert!(matches!(
            roundtrip_leader(&ToLeader::ReduceDone { phase: 9, lo: 0, band: 0 }),
            ToLeader::ReduceDone { phase: 9, lo: 0, band: 0 }
        ));
        match roundtrip_leader(&ToLeader::ReduceFailed {
            phase: 9,
            lo: 0,
            band: 0,
            message: "no held operand".into(),
        }) {
            ToLeader::ReduceFailed { message, .. } => assert_eq!(message, "no held operand"),
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn coded_matrix_shrinks_smooth_payloads_and_roundtrips_exactly() {
        // Smooth column-major-ish data: XOR-delta beats raw by a wide
        // margin, and the decode is bit-exact.
        let m = Matrix::from_fn(64, 8, |i, j| 1.0 + (i * 8 + j) as f64 * 1e-9);
        let mut coded = Vec::new();
        put_coded_matrix(&mut coded, &m, true);
        let mut raw = Vec::new();
        put_coded_matrix(&mut raw, &m, false);
        assert!(coded.len() < raw.len(), "coded {} raw {}", coded.len(), raw.len());
        let got = Cursor::new(&coded).coded_matrix().unwrap();
        assert_eq!(got.max_abs_diff(&m), 0.0);
        let got = Cursor::new(&raw).coded_matrix().unwrap();
        assert_eq!(got.max_abs_diff(&m), 0.0);
        // Unknown encoding byte is rejected.
        let mut bad = raw.clone();
        bad[8] = 7;
        assert!(Cursor::new(&bad).coded_matrix().is_err());
    }

    #[test]
    fn chunk_done_roundtrip() {
        let m = Matrix::from_fn(4, 4, |i, j| (i + j) as f64);
        let msg = ToLeader::ChunkDone {
            phase: 3,
            chunk: 9,
            rows: 999,
            decode_us: 1500,
            compute_us: 8000,
            encode_us: 250,
            partial: m.clone(),
        };
        match roundtrip_leader(&msg) {
            ToLeader::ChunkDone {
                phase,
                chunk,
                rows,
                decode_us,
                compute_us,
                encode_us,
                partial,
            } => {
                assert_eq!((phase, chunk, rows), (3, 9, 999));
                assert_eq!((decode_us, compute_us, encode_us), (1500, 8000, 250));
                assert_eq!(partial.max_abs_diff(&m), 0.0);
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn chunk_failed_roundtrip() {
        let msg =
            ToLeader::ChunkFailed { phase: 5, chunk: 2, message: "disk on fire".into() };
        match roundtrip_leader(&msg) {
            ToLeader::ChunkFailed { phase, chunk, message } => {
                assert_eq!((phase, chunk), (5, 2));
                assert_eq!(message, "disk on fire");
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn truncated_frame_is_error() {
        let mut buf = Vec::new();
        ToLeader::ChunkDone {
            phase: 1,
            chunk: 0,
            rows: 1,
            decode_us: 0,
            compute_us: 0,
            encode_us: 0,
            partial: Matrix::zeros(2, 2),
        }
        .write(&mut buf)
        .unwrap();
        buf.truncate(buf.len() - 3);
        assert!(ToLeader::read(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        buf.push(T_CHUNK_DONE);
        assert!(ToLeader::read(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn zero_size_matrix_roundtrips() {
        let msg = ToLeader::ChunkDone {
            phase: 0,
            chunk: 0,
            rows: 0,
            decode_us: 0,
            compute_us: 0,
            encode_us: 0,
            partial: Matrix::zeros(0, 0),
        };
        match roundtrip_leader(&msg) {
            ToLeader::ChunkDone { partial, .. } => assert_eq!(partial.shape(), (0, 0)),
            other => panic!("wrong message: {other:?}"),
        }
    }
}
