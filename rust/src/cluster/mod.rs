//! Multi-process Split-Process: the paper's actual deployment, with
//! chunk-grained dynamic scheduling and fault tolerance.
//!
//! The paper's §1 deployment is "each process on each machine has access to
//! a large file ... either through copies of that file being in each
//! machine, or through a shared file server". The in-process
//! [`crate::splitproc`] engine demonstrates the algorithm; this module runs
//! it across real OS processes over TCP:
//!
//! * the **leader** (`tallfat svd --distributed --listen addr --remote-workers N`)
//!   listens, broadcasts one `Phase` setup per pass (the small shared
//!   operands), then streams `Assign { chunk }` tasks from a work queue —
//!   many more chunks than workers (`--chunks-per-worker` /
//!   `--chunk-rows`), each acked individually;
//! * each **worker** (`tallfat worker --leader addr`) computes chunk
//!   geometry locally from the shared file (deterministic
//!   [`crate::splitproc::plan_chunks`] — both sides see the same bytes),
//!   streams each assigned chunk through the same jobs the in-process
//!   engine uses, and ships back its `k' x k'` / `n x k'` partial per
//!   chunk. Y/U shards are written to the shared filesystem, exactly like
//!   the paper's `/tmp/C-%d.csv`, staged and atomically renamed.
//!
//! The chunk lifecycle under failure (see [`crate::splitproc::sched`]):
//!
//! ```text
//! planned -> queued -> assigned -> done        (first completion wins)
//!               ^          |
//!               +- requeued+   worker died / chunk failed within budget
//! ```
//!
//! A dying worker's in-flight chunks requeue with that worker excluded; a
//! worker silent past the heartbeat deadline is fenced the same way; a
//! worker connecting mid-pass is handed the current setup and starts
//! pulling queued chunks; and once the queue drains, idle workers
//! speculatively duplicate the longest-running chunks. A pass fails only
//! when a chunk exhausts its retry budget (the error names the chunk) or
//! no live worker can take the remaining work.
//!
//! Only *small* state crosses the wire (sketch partials, rotation
//! matrices); the tall data never does — that is the paper's point, and the
//! protocol makes it structural: [`proto`] has no frame type for row data.
//!
//! **Distributed reduce** (proto v6): in the default `--reduce tree` mode
//! workers *hold* their summed `k' x k'` partials instead of shipping them,
//! and the leader relays `log2(workers)` rounds of pairwise merges
//! (`RMerge` / `RFetch`) between holders — leader state stays
//! `O(k'^2 log w)` instead of `O(n k')`. The final `W` pass reduces tall
//! partials as banded TSQR R factors and workers write V row shards
//! straight to the shared filesystem (`RWriteV`), so the leader never
//! materializes an n-sized matrix. Old (v5, capability-less) workers still
//! join: a worker that never advertised `CAP_HOLD` just ships its partial
//! and the leader folds it in at the root. `--reduce star` restores the
//! old ship-everything topology.
//!
//! The SVD math never lives here: [`ClusterExecutor`] plugs this transport
//! into the one executor-generic pipeline in [`crate::svd`] —
//! `Svd::over(&input)?.executor(&mut cluster).run()` runs the exact same
//! pass schedule the local executor does, and reduces per-chunk partials
//! in the same chunk order, so the factors match bit for bit — tree or
//! star, local or distributed.
//!
//! The protocol is a hand-rolled length-prefixed binary format ([`proto`]) —
//! serde is unavailable offline, and the message set is small.

pub mod executor;
pub mod leader;
pub mod proto;
pub mod worker;

pub use executor::ClusterExecutor;
pub use leader::DistributedLeader;
pub use worker::run_worker;

pub(crate) use executor::pass_from_wire;
