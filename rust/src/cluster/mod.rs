//! Multi-process Split-Process: the paper's actual deployment.
//!
//! The paper's §1 deployment is "each process on each machine has access to
//! a large file ... either through copies of that file being in each
//! machine, or through a shared file server". The in-process
//! [`crate::splitproc`] engine demonstrates the algorithm; this module runs
//! it across real OS processes over TCP:
//!
//! * the **leader** (`tallfat svd --distributed --listen addr --remote-workers N`)
//!   listens, hands each connecting worker a phase assignment (chunk index
//!   + the small shared operands), and reduces the returned partials;
//! * each **worker** (`tallfat worker --leader addr`) computes chunk
//!   geometry locally from the shared file (deterministic
//!   [`crate::splitproc::plan_chunks`] — both sides see the same bytes),
//!   streams its rows through the same jobs the in-process engine uses, and
//!   ships back its `k' x k'` / `n x k'` partial. Y/U shards are written to
//!   the shared filesystem, exactly like the paper's `/tmp/C-%d.csv`.
//!
//! Only *small* state crosses the wire (sketch partials, rotation
//! matrices); the tall data never does — that is the paper's point, and the
//! protocol makes it structural: [`proto`] has no frame type for row data.
//!
//! The SVD math never lives here: [`ClusterExecutor`] plugs this transport
//! into the one executor-generic pipeline in [`crate::svd`] —
//! `Svd::over(&input)?.executor(&mut cluster).run()` runs the exact same
//! pass schedule the local executor does.
//!
//! The protocol is a hand-rolled length-prefixed binary format ([`proto`]) —
//! serde is unavailable offline, and the message set is 6 frames.

pub mod executor;
pub mod leader;
pub mod proto;
pub mod worker;

pub use executor::ClusterExecutor;
pub use leader::DistributedLeader;
pub use worker::run_worker;

pub(crate) use executor::pass_from_wire;
