//! The leader: accepts worker connections, broadcasts phase assignments,
//! collects partials. The SVD math itself lives in [`crate::svd::pipeline`]
//! — this module is pure transport, driven through
//! [`crate::cluster::ClusterExecutor`].

use super::proto::{PhaseKind, ToLeader, ToWorker, VERSION};
use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::InputSpec;
use crate::linalg::Matrix;
use crate::util::Logger;
use std::net::{TcpListener, TcpStream};

static LOG: Logger = Logger::new("cluster.leader");

/// One connected worker.
struct WorkerConn {
    stream: TcpStream,
}

impl WorkerConn {
    fn send(&mut self, msg: &ToWorker) -> Result<()> {
        msg.write(&mut self.stream)
    }

    fn recv(&mut self) -> Result<ToLeader> {
        ToLeader::read(&mut self.stream)
    }
}

/// Accepts workers, runs phases, reduces partials.
pub struct DistributedLeader {
    workers: Vec<WorkerConn>,
}

impl DistributedLeader {
    /// Bind `listen` and wait for exactly `n` workers to say hello.
    pub fn accept(listen: &str, n: usize) -> Result<Self> {
        if n == 0 {
            return Err(Error::Config("remote-workers must be >= 1".into()));
        }
        let listener = TcpListener::bind(listen)?;
        LOG.info(&format!("leader on {listen}, waiting for {n} workers"));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let (stream, peer) = listener.accept()?;
            stream.set_nodelay(true).ok();
            let mut conn = WorkerConn { stream };
            match conn.recv()? {
                ToLeader::Hello { version } if version == VERSION => {
                    LOG.info(&format!("worker {i} joined from {peer}"));
                    workers.push(conn);
                }
                ToLeader::Hello { version } => {
                    return Err(Error::Config(format!(
                        "worker {peer} speaks protocol v{version}, leader v{VERSION}"
                    )));
                }
                other => {
                    return Err(Error::parse(format!("expected hello, got {other:?}")));
                }
            }
        }
        Ok(DistributedLeader { workers })
    }

    /// Number of connected workers.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Run one phase on all workers (worker i gets chunk i) and collect
    /// `(total_rows, partials)`.
    #[allow(clippy::too_many_arguments)]
    pub fn run_phase(
        &mut self,
        kind: PhaseKind,
        input: &InputSpec,
        work_dir: &str,
        block: usize,
        seed: u64,
        kp: usize,
        cols: usize,
        shard_format: InputFormat,
        operand: &Matrix,
        means: &Matrix,
    ) -> Result<(u64, Vec<Matrix>)> {
        // Frame-alignment invariant: the executor seam keeps leaders alive
        // across passes, so this must never leave a connection with an
        // unread reply in flight. Send to every worker (recording, not
        // returning, the first error), then read a reply from exactly the
        // workers a phase was delivered to.
        let total = self.workers.len() as u32;
        let mut failure: Option<Error> = None;
        let mut sent = vec![false; self.workers.len()];
        for (i, w) in self.workers.iter_mut().enumerate() {
            let r = w.send(&ToWorker::Phase {
                kind,
                input_path: input.path.clone(),
                input_format: input.format,
                work_dir: work_dir.to_string(),
                chunk_index: i as u32,
                chunk_total: total,
                block: block as u32,
                seed,
                kp: kp as u32,
                cols: cols as u32,
                shard_format,
                operand: operand.clone(),
                means: means.clone(),
            });
            match r {
                Ok(()) => sent[i] = true,
                Err(e) => {
                    if failure.is_none() {
                        failure = Some(Error::Other(format!("send to worker {i} failed: {e}")));
                    }
                }
            }
        }
        let mut rows = 0u64;
        let mut partials = Vec::with_capacity(self.workers.len());
        for (i, w) in self.workers.iter_mut().enumerate() {
            if !sent[i] {
                continue;
            }
            match w.recv() {
                Ok(ToLeader::Partial { rows: r, partial }) => {
                    rows += r;
                    if partial.rows() > 0 {
                        partials.push(partial);
                    }
                }
                Ok(ToLeader::Failed { message }) => {
                    if failure.is_none() {
                        failure = Some(Error::Other(format!("worker {i} failed: {message}")));
                    }
                }
                Ok(other) => {
                    if failure.is_none() {
                        failure = Some(Error::parse(format!("unexpected reply: {other:?}")));
                    }
                }
                // Connection-level error: this stream is gone either way;
                // keep draining the rest so they stay aligned.
                Err(e) => {
                    if failure.is_none() {
                        failure = Some(e);
                    }
                }
            }
        }
        match failure {
            Some(e) => Err(e),
            None => Ok((rows, partials)),
        }
    }

    /// Tell every worker to exit. A dead connection must not stop the
    /// others from being told — send to all, report the first error.
    pub fn shutdown(&mut self) -> Result<()> {
        let mut failure: Option<Error> = None;
        for w in &mut self.workers {
            if let Err(e) = w.send(&ToWorker::Shutdown) {
                if failure.is_none() {
                    failure = Some(e);
                }
            }
        }
        match failure {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}
