//! The leader: accepts worker connections, streams chunk assignments to
//! them, and collects per-chunk acks. The SVD math itself lives in
//! [`crate::svd::pipeline`] — this module is transport plus the cluster
//! side of the chunk scheduler and the reduction plan
//! ([`crate::svd::reduce`]), driven through
//! [`crate::cluster::ClusterExecutor`].
//!
//! One recv thread per worker turns every connection into an event stream
//! (`ChunkDone` / `ChunkFailed` / `Heartbeat` / death); the leader's event
//! loop feeds a [`ChunkScheduler`]:
//!
//! * a worker finishing a chunk immediately gets the next queued chunk —
//!   fast workers drain the queue, slow ones don't gate it;
//! * a worker dying mid-chunk requeues its chunk with that worker
//!   excluded, and a worker silent past [`STALE_AFTER_MS`] (no heartbeat)
//!   is fenced the same way;
//! * a worker connecting mid-run (the background accept loop keeps the
//!   listen socket open) is sent the current phase setup and starts
//!   pulling queued chunks;
//! * once the queue drains, idle workers speculatively re-execute the
//!   longest-running chunks; the first completion wins, duplicates are
//!   dropped (shard writes are staged + atomically renamed, so a late
//!   duplicate is harmless).
//!
//! Reductions follow one of two plans. **Star** ([`run_phase`]): every
//! partial rides its `ChunkDone` frame and the leader stores them all —
//! `O(chunks)` leader memory, accounted by the [`MemGauge`]. **Tree**
//! ([`run_phase_tree`] / [`run_wphase`]): [`CAP_HOLD`] workers keep their
//! partial as held leaves and ship an empty ack; the leader then walks the
//! canonical [`merge_rounds`] schedule, relaying pairwise `RMerge` steps
//! between holders, so it only ever touches one `k'`-scale message in
//! transit. The tall `W` reduction ([`run_wphase`]) additionally band-splits
//! leaves, folds per-band TSQR R factors into the completion's `(Σ, P)`,
//! and has the root holder write `V` row shards directly — the leader never
//! materializes an n-sized factor. A holder dying or failing mid-reduce
//! aborts the attempt; the whole phase restarts under a fresh id (bounded
//! by the retry budget), which is safe because chunk execution is
//! deterministic and shard writes are staged.
//!
//! [`run_phase`]: DistributedLeader::run_phase
//! [`run_phase_tree`]: DistributedLeader::run_phase_tree
//! [`run_wphase`]: DistributedLeader::run_wphase
//! [`CAP_HOLD`]: super::proto::CAP_HOLD
//! [`merge_rounds`]: crate::svd::reduce::merge_rounds

use super::proto::{FetchWhat, PhaseKind, ToLeader, ToWorker, HOLD_NONE, MIN_VERSION, VERSION};
use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::writer::ShardSet;
use crate::io::InputSpec;
use crate::linalg::{matmul, Matrix};
use crate::obs::trace::{self, next_id, Span, TraceCtx, TraceEvent};
use crate::splitproc::{ChunkScheduler, SchedStats};
use crate::svd::reduce::{self, MemGauge, MergeStep};
use crate::util::Logger;
use std::collections::{HashMap, HashSet};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

static LOG: Logger = Logger::new("cluster.leader");

/// A worker silent for this long (no frame, no heartbeat — the heartbeat
/// period is [`super::worker::HEARTBEAT_MS`]) is treated as dead and its
/// in-flight chunk requeued.
pub const STALE_AFTER_MS: u64 = 10_000;

/// Event-loop poll period when no events arrive (drives the staleness
/// sweep).
const EVENT_POLL_MS: u64 = 1_000;

/// Trace lane for merged worker chunk events: lane = base + worker index.
/// Kept clear of the leader's own small per-thread lane ids.
const WORKER_LANE_BASE: u64 = 100;

/// Everything one phase needs besides the reduction plan: what the old
/// 13-argument `run_phase` took, named. Borrowed so call sites don't clone
/// operands.
pub struct PhaseSpec<'a> {
    pub kind: PhaseKind,
    pub input: &'a InputSpec,
    pub work_dir: &'a str,
    pub block: usize,
    pub seed: u64,
    pub kp: usize,
    pub cols: usize,
    pub shard_format: InputFormat,
    pub shard_epoch: u32,
    pub operand: &'a Matrix,
    pub means: &'a Matrix,
    pub chunk_total: usize,
    pub max_retries: usize,
}

/// One connected worker, leader-side: the write half of its socket plus
/// scheduling state. The read half lives in its recv thread.
struct Worker {
    stream: TcpStream,
    /// Peer address, for logs and trace attribution.
    peer: String,
    /// Capability bitmap from the worker's hello (0 for v5 workers).
    caps: u64,
    alive: bool,
    /// The `(phase, chunk)` assignment in flight, if any (workers execute
    /// one chunk at a time).
    busy: Option<(u64, u32)>,
    busy_since: Instant,
    last_seen: Instant,
    /// Span id of the in-flight assignment (0 when the run isn't traced);
    /// the merged timeline event for the chunk reuses it, so the worker's
    /// logs and the leader's event carry the same span.
    assign_span: u64,
    /// The in-flight assignment re-runs a chunk that was assigned before
    /// (failure retry or death requeue).
    assign_retry: bool,
    /// The in-flight assignment is a speculative duplicate.
    assign_speculative: bool,
}

enum Event {
    Msg { worker: usize, msg: ToLeader },
    Dead { worker: usize, error: String },
    Joined { stream: TcpStream, caps: u64 },
}

/// Where a reduce span's leaves live: on the worker that computed (or
/// merged into) them, or leader-side when a hold-incapable v5 worker
/// shipped the partial the old way (one matrix per band).
enum Hold {
    Worker(usize),
    Leader(Vec<Matrix>),
}

/// Leader-resident bytes of a hold — the [`MemGauge`] accounting unit.
fn hold_bytes(h: &Hold) -> u64 {
    match h {
        Hold::Worker(_) => 0,
        Hold::Leader(bands) => bands.iter().map(reduce::matrix_bytes).sum(),
    }
}

/// Outcome of one tree-reduce attempt step: finished, or the attempt must
/// restart from chunk execution (holder died, reduce step failed).
enum TreeFlow<T> {
    Done(T),
    Restart(String),
}

/// What [`DistributedLeader::await_reduce`] resolved to.
enum ReduceReply {
    Part(Matrix),
    Done,
}

/// Result of driving one phase's chunks to completion.
struct ChunkDrive {
    phase_id: u64,
    rows: u64,
    /// Leader-stored partials, chunk-ordered (star mode, and tree-mode
    /// leaves from hold-incapable workers).
    partials: Vec<Option<Matrix>>,
    /// Tree mode: which worker holds chunk `c`'s leaves (empty `ChunkDone`
    /// partial from a `CAP_HOLD` worker).
    holder_worker: Vec<Option<usize>>,
    /// Gauge bytes tracked for `partials` (released by the caller when the
    /// partials are consumed or the attempt aborts).
    tracked: u64,
    stats: Option<SchedStats>,
}

fn send_to(worker: &mut Worker, msg: &ToWorker) -> Result<()> {
    let mut stream: &TcpStream = &worker.stream;
    msg.write_caps(&mut stream, worker.caps)
}

fn recv_loop(mut reader: TcpStream, id: usize, tx: Sender<Event>) {
    loop {
        match ToLeader::read(&mut reader) {
            Ok(msg) => {
                if tx.send(Event::Msg { worker: id, msg }).is_err() {
                    return;
                }
            }
            Err(e) => {
                let _ = tx.send(Event::Dead { worker: id, error: e.to_string() });
                return;
            }
        }
    }
}

fn accept_loop(listener: TcpListener, tx: Sender<Event>, stop: Arc<AtomicBool>) {
    loop {
        let accepted = listener.accept();
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let Ok((stream, peer)) = accepted else { continue };
        stream.set_nodelay(true).ok();
        // Bound the hello wait so a rogue silent connection can't wedge
        // late joins forever.
        stream.set_read_timeout(Some(Duration::from_secs(2))).ok();
        let hello = {
            let mut rs: &TcpStream = &stream;
            ToLeader::read(&mut rs)
        };
        match hello {
            Ok(ToLeader::Hello { version, caps })
                if (MIN_VERSION..=VERSION).contains(&version) =>
            {
                stream.set_read_timeout(None).ok();
                LOG.info(&format!("late worker from {peer} verified (v{version}, caps {caps:#x})"));
                if tx.send(Event::Joined { stream, caps }).is_err() {
                    return;
                }
            }
            Ok(ToLeader::Hello { version, .. }) => {
                LOG.warn(&format!("rejected {peer}: protocol v{version}, leader v{VERSION}"));
            }
            Ok(other) => {
                LOG.warn(&format!("rejected {peer}: expected hello, got {other:?}"));
            }
            Err(e) => {
                LOG.warn(&format!("rejected {peer}: {e}"));
            }
        }
    }
}

/// Accepts workers, schedules chunk-grained phases, reduces partials —
/// star or tree, per the caller's reduction plan.
pub struct DistributedLeader {
    workers: Vec<Worker>,
    events: Receiver<Event>,
    events_tx: Sender<Event>,
    listen_addr: String,
    stop_accept: Arc<AtomicBool>,
    next_phase: u64,
    gauge: MemGauge,
}

impl DistributedLeader {
    /// Bind `listen` and wait for exactly `n` workers to say hello; the
    /// listen socket then stays open in the background so more workers can
    /// join any later pass mid-run.
    pub fn accept(listen: &str, n: usize) -> Result<Self> {
        if n == 0 {
            return Err(Error::Config("remote-workers must be >= 1".into()));
        }
        let listener = TcpListener::bind(listen)?;
        let listen_addr = listener.local_addr()?.to_string();
        LOG.info(&format!("leader on {listen_addr}, waiting for {n} workers"));
        let (events_tx, events) = mpsc::channel();
        let mut leader = DistributedLeader {
            workers: Vec::new(),
            events,
            events_tx,
            listen_addr,
            stop_accept: Arc::new(AtomicBool::new(false)),
            next_phase: 0,
            gauge: MemGauge::default(),
        };
        for i in 0..n {
            let (stream, peer) = listener.accept()?;
            stream.set_nodelay(true).ok();
            let hello = {
                let mut rs: &TcpStream = &stream;
                ToLeader::read(&mut rs)?
            };
            match hello {
                ToLeader::Hello { version, caps }
                    if (MIN_VERSION..=VERSION).contains(&version) =>
                {
                    LOG.info(&format!(
                        "worker {i} joined from {peer} (v{version}, caps {caps:#x})"
                    ));
                    leader.register(stream, caps)?;
                }
                ToLeader::Hello { version, .. } => {
                    return Err(Error::Config(format!(
                        "worker {peer} speaks protocol v{version}, leader v{VERSION}"
                    )));
                }
                other => {
                    return Err(Error::parse(format!("expected hello, got {other:?}")));
                }
            }
        }
        let tx = leader.events_tx.clone();
        let stop = leader.stop_accept.clone();
        std::thread::spawn(move || accept_loop(listener, tx, stop));
        Ok(leader)
    }

    /// Add a verified worker connection: spawn its recv thread, track its
    /// write half. The hello must already have been consumed.
    fn register(&mut self, stream: TcpStream, caps: u64) -> Result<usize> {
        let id = self.workers.len();
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| format!("worker-{id}"));
        let reader = stream.try_clone()?;
        let tx = self.events_tx.clone();
        std::thread::spawn(move || recv_loop(reader, id, tx));
        self.workers.push(Worker {
            stream,
            peer,
            caps,
            alive: true,
            busy: None,
            busy_since: Instant::now(),
            last_seen: Instant::now(),
            assign_span: 0,
            assign_retry: false,
            assign_speculative: false,
        });
        Ok(id)
    }

    /// Number of live workers.
    pub fn worker_count(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).count()
    }

    /// Cap the leader's tracked reduce-state bytes (0 = track only). A
    /// phase whose reduce state would exceed the cap fails instead of
    /// growing — how the memory-cap tests prove the star path can't
    /// complete where the tree path fits.
    pub fn set_mem_cap(&mut self, bytes: u64) {
        self.gauge.set_cap(bytes);
    }

    /// High-water mark of leader-resident reduce-state bytes.
    pub fn mem_peak(&self) -> u64 {
        self.gauge.peak()
    }

    fn mark_dead(&mut self, w: usize, why: &str) {
        if self.workers[w].alive {
            LOG.warn(&format!("worker {w}: {why}: marking dead"));
            self.workers[w].alive = false;
            self.workers[w].busy = None;
        }
    }

    fn send_worker(&mut self, w: usize, msg: &ToWorker) -> Result<()> {
        send_to(&mut self.workers[w], msg)
    }

    /// Run one phase with the **star** reduction plan: every partial rides
    /// its `ChunkDone` frame, the leader stores all of them (gauge-tracked)
    /// and returns `(total_rows, partials_in_chunk_order, stats)`.
    pub fn run_phase(&mut self, spec: &PhaseSpec) -> Result<(u64, Vec<Matrix>, SchedStats)> {
        match self.drive_chunks(spec, false, 0)? {
            TreeFlow::Done(mut d) => {
                self.gauge.release(d.tracked);
                let stats = d.stats.take().ok_or_else(|| {
                    Error::Other("phase finished without scheduler stats".into())
                })?;
                let ordered: Vec<Matrix> = d.partials.into_iter().flatten().collect();
                Ok((d.rows, ordered, stats))
            }
            TreeFlow::Restart(r) => {
                Err(Error::Other(format!("star phase requested a restart: {r}")))
            }
        }
    }

    /// Run one phase with the **tree** reduction plan: `CAP_HOLD` workers
    /// keep their partial as a held leaf, the leader drives the canonical
    /// pairwise merge schedule between holders, and only the final root
    /// crosses to the leader. A holder dying mid-reduce restarts the whole
    /// attempt (fresh phase id, all chunks re-run) within the retry budget.
    pub fn run_phase_tree(&mut self, spec: &PhaseSpec) -> Result<(u64, Matrix, SchedStats)> {
        let attempts = spec.max_retries.max(1) + 1;
        let mut last = String::new();
        for attempt in 0..attempts {
            if attempt > 0 {
                LOG.warn(&format!(
                    "restarting {} tree reduce (attempt {} of {attempts}): {last}",
                    spec.kind.name(),
                    attempt + 1
                ));
            }
            match self.try_tree(spec)? {
                TreeFlow::Done(out) => return Ok(out),
                TreeFlow::Restart(reason) => last = reason,
            }
        }
        Err(Error::Other(format!(
            "{} tree reduce failed after {attempts} attempts: {last}",
            spec.kind.name()
        )))
    }

    /// Run the tall-`W` pass with the **tree** plan: held leaves are
    /// band-split, merged pairwise per band, folded into one `k'×k'` TSQR
    /// R factor whose SVD is the completion's `(Σ_full, P)`, and — when
    /// `compute_v` — each root band times `M_v = P_k Σ_k⁻¹` is written as a
    /// row shard of the staged `V` [`ShardSet`] by whoever holds it. The
    /// leader never materializes an n-sized matrix. Returns
    /// `(rows, sigma_full, p, v_bands, stats)`.
    #[allow(clippy::type_complexity)]
    pub fn run_wphase(
        &mut self,
        spec: &PhaseSpec,
        band_rows: u64,
        k: usize,
        cutoff_rel: f64,
        compute_v: bool,
    ) -> Result<(u64, Vec<f64>, Matrix, usize, SchedStats)> {
        let attempts = spec.max_retries.max(1) + 1;
        let mut last = String::new();
        for attempt in 0..attempts {
            if attempt > 0 {
                LOG.warn(&format!(
                    "restarting {} W reduction (attempt {} of {attempts}): {last}",
                    spec.kind.name(),
                    attempt + 1
                ));
            }
            match self.try_wphase(spec, band_rows, k, cutoff_rel, compute_v)? {
                TreeFlow::Done(out) => return Ok(out),
                TreeFlow::Restart(reason) => last = reason,
            }
        }
        Err(Error::Other(format!(
            "{} W reduction failed after {attempts} attempts: {last}",
            spec.kind.name()
        )))
    }

    /// One tree-reduce attempt: drive chunks in hold mode (one band), walk
    /// the merge schedule, fetch the root.
    fn try_tree(&mut self, spec: &PhaseSpec) -> Result<TreeFlow<(u64, Matrix, SchedStats)>> {
        let mut d = match self.drive_chunks(spec, true, 0)? {
            TreeFlow::Done(d) => d,
            TreeFlow::Restart(r) => return Ok(TreeFlow::Restart(r)),
        };
        let phase_id = d.phase_id;
        let rows = d.rows;
        let stats = d
            .stats
            .take()
            .ok_or_else(|| Error::Other("phase finished without scheduler stats".into()))?;
        let mut holders = self.build_holders(&mut d, 0)?;
        match self.drive_merges(phase_id, &mut holders, spec.chunk_total, 1)? {
            TreeFlow::Done(()) => {}
            TreeFlow::Restart(r) => {
                self.release_holders(&holders);
                return Ok(TreeFlow::Restart(r));
            }
        }
        let root = holders
            .remove(&0)
            .ok_or_else(|| Error::Other("tree reduce left no root".into()))?;
        match root {
            Hold::Leader(mut bands) => {
                let m = bands
                    .pop()
                    .ok_or_else(|| Error::Other("leader-held root has no band".into()))?;
                self.gauge.release(reduce::matrix_bytes(&m));
                Ok(TreeFlow::Done((rows, m, stats)))
            }
            Hold::Worker(w) => {
                let fetch =
                    ToWorker::RFetch { phase: phase_id, lo: 0, band: 0, what: FetchWhat::Partial };
                if let Err(e) = self.send_worker(w, &fetch) {
                    self.mark_dead(w, &e.to_string());
                    return Ok(TreeFlow::Restart(format!("root fetch send failed: {e}")));
                }
                let watch = HashSet::new();
                match self.await_reduce(phase_id, w, 0, 0, &watch)? {
                    TreeFlow::Done(ReduceReply::Part(m)) => {
                        // Account the root's one transit through the leader.
                        let bytes = reduce::matrix_bytes(&m);
                        self.gauge.track(bytes)?;
                        self.gauge.release(bytes);
                        Ok(TreeFlow::Done((rows, m, stats)))
                    }
                    TreeFlow::Done(ReduceReply::Done) => {
                        Err(Error::Other("expected root partial, got ack".into()))
                    }
                    TreeFlow::Restart(r) => Ok(TreeFlow::Restart(r)),
                }
            }
        }
    }

    /// One W-reduction attempt: banded hold, per-band merges, R-factor
    /// fold, completion, V shard writes.
    #[allow(clippy::type_complexity)]
    fn try_wphase(
        &mut self,
        spec: &PhaseSpec,
        band_rows: u64,
        k: usize,
        cutoff_rel: f64,
        compute_v: bool,
    ) -> Result<TreeFlow<(u64, Vec<f64>, Matrix, usize, SchedStats)>> {
        let eff = if band_rows == 0 { reduce::auto_band_rows(spec.kp) as u64 } else { band_rows };
        let mut d = match self.drive_chunks(spec, true, eff)? {
            TreeFlow::Done(d) => d,
            TreeFlow::Restart(r) => return Ok(TreeFlow::Restart(r)),
        };
        let phase_id = d.phase_id;
        let rows = d.rows;
        let stats = d
            .stats
            .take()
            .ok_or_else(|| Error::Other("phase finished without scheduler stats".into()))?;
        // Every chunk's W partial is the same full n×k' additive shape, so
        // every holder derives the identical band split.
        let n_bands = reduce::band_ranges(spec.cols, eff as usize).len();
        let mut holders = self.build_holders(&mut d, eff as usize)?;
        match self.drive_merges(phase_id, &mut holders, spec.chunk_total, n_bands)? {
            TreeFlow::Done(()) => {}
            TreeFlow::Restart(r) => {
                self.release_holders(&holders);
                return Ok(TreeFlow::Restart(r));
            }
        }
        let root = holders
            .remove(&0)
            .ok_or_else(|| Error::Other("W reduction left no root".into()))?;
        let root_bytes = hold_bytes(&root);
        // Gather per-band R factors: fetched k'×k' matrices from a worker
        // root (held bands are kept for the V writes), or computed locally
        // from leader-held bands.
        let mut rs_bytes = 0u64;
        let rs: Vec<Matrix> = match &root {
            Hold::Worker(w) => {
                let w = *w;
                let watch = HashSet::new();
                let mut rs = Vec::with_capacity(n_bands);
                for band in 0..n_bands as u32 {
                    let fetch = ToWorker::RFetch {
                        phase: phase_id,
                        lo: 0,
                        band,
                        what: FetchWhat::RFactor,
                    };
                    if let Err(e) = self.send_worker(w, &fetch) {
                        self.mark_dead(w, &e.to_string());
                        self.gauge.release(rs_bytes);
                        return Ok(TreeFlow::Restart(format!("R-factor fetch send failed: {e}")));
                    }
                    match self.await_reduce(phase_id, w, 0, band, &watch)? {
                        TreeFlow::Done(ReduceReply::Part(r)) => {
                            let b = reduce::matrix_bytes(&r);
                            self.gauge.track(b)?;
                            rs_bytes += b;
                            rs.push(r);
                        }
                        TreeFlow::Done(ReduceReply::Done) => {
                            return Err(Error::Other("expected R factor, got ack".into()));
                        }
                        TreeFlow::Restart(r) => {
                            self.gauge.release(rs_bytes);
                            return Ok(TreeFlow::Restart(r));
                        }
                    }
                }
                rs
            }
            Hold::Leader(bands) => {
                let mut rs = Vec::with_capacity(bands.len());
                for b in bands {
                    rs.push(reduce::band_r_factor(b)?);
                }
                rs
            }
        };
        let r = reduce::fold_band_rs(spec.kp, rs)?;
        self.gauge.release(rs_bytes);
        let (sigma_full, p) = reduce::completion_from_r(&r)?;
        let v_bands = if compute_v {
            let mv = reduce::completion_mv(&sigma_full, &p, k, cutoff_rel)?;
            match &root {
                Hold::Worker(w) => {
                    let w = *w;
                    let watch = HashSet::new();
                    for band in 0..n_bands as u32 {
                        let msg = ToWorker::RWriteV {
                            phase: phase_id,
                            lo: 0,
                            band,
                            shard: band,
                            mv: mv.clone(),
                        };
                        if let Err(e) = self.send_worker(w, &msg) {
                            self.mark_dead(w, &e.to_string());
                            return Ok(TreeFlow::Restart(format!("V shard write send failed: {e}")));
                        }
                        match self.await_reduce(phase_id, w, 0, band, &watch)? {
                            TreeFlow::Done(ReduceReply::Done) => {}
                            TreeFlow::Done(ReduceReply::Part(_)) => {
                                return Err(Error::Other("expected write ack, got matrix".into()));
                            }
                            TreeFlow::Restart(r) => return Ok(TreeFlow::Restart(r)),
                        }
                    }
                }
                Hold::Leader(bands) => {
                    let set = ShardSet::new(spec.work_dir, "V", spec.shard_format)?;
                    for (b, wband) in bands.iter().enumerate() {
                        let v = matmul(wband, &mv)?;
                        let mut wr = set.open_writer(b, v.cols())?;
                        for i in 0..v.rows() {
                            wr.write_row(v.row(i))?;
                        }
                        wr.finish()?;
                    }
                }
            }
            n_bands
        } else {
            0
        };
        self.gauge.release(root_bytes);
        Ok(TreeFlow::Done((rows, sigma_full, p, v_bands, stats)))
    }

    /// Turn a finished chunk drive into the merge schedule's leaf map:
    /// chunk `c`'s leaves live on their holder worker, or leader-side
    /// (band-split) when the worker shipped the partial the v5 way.
    fn build_holders(
        &mut self,
        d: &mut ChunkDrive,
        band_rows: usize,
    ) -> Result<HashMap<u32, Hold>> {
        // Accounting moves from the drive's bulk `tracked` counter to
        // per-hold tracking (net change zero; the peak was already seen).
        self.gauge.release(d.tracked);
        d.tracked = 0;
        let mut holders = HashMap::new();
        for c in 0..d.holder_worker.len() {
            let h = if let Some(w) = d.holder_worker[c] {
                Hold::Worker(w)
            } else if let Some(p) = d.partials[c].take() {
                let bands: Vec<Matrix> = reduce::band_ranges(p.rows(), band_rows)
                    .into_iter()
                    .map(|(lo, hi)| p.slice_rows(lo, hi))
                    .collect();
                Hold::Leader(bands)
            } else {
                return Err(Error::Other(format!("chunk {c} produced no reduce leaf")));
            };
            self.gauge.track(hold_bytes(&h))?;
            holders.insert(c as u32, h);
        }
        Ok(holders)
    }

    /// Walk the canonical merge schedule over the leaf map, one pairwise
    /// merge at a time. Gauge accounting is exact at step boundaries:
    /// operands are released when removed from the map, results tracked
    /// when inserted, and wire transits tracked inside the relay.
    fn drive_merges(
        &mut self,
        phase_id: u64,
        holders: &mut HashMap<u32, Hold>,
        total: usize,
        n_bands: usize,
    ) -> Result<TreeFlow<()>> {
        for round in reduce::merge_rounds(total) {
            for MergeStep { dst, src } in round {
                let dst_k = dst as u32;
                let src_k = src as u32;
                let left = holders
                    .remove(&dst_k)
                    .ok_or_else(|| Error::Other(format!("merge schedule missing leaf {dst}")))?;
                let right = holders
                    .remove(&src_k)
                    .ok_or_else(|| Error::Other(format!("merge schedule missing leaf {src}")))?;
                self.gauge.release(hold_bytes(&left) + hold_bytes(&right));
                let watch: HashSet<usize> = holders
                    .values()
                    .filter_map(|h| match h {
                        Hold::Worker(w) => Some(*w),
                        Hold::Leader(_) => None,
                    })
                    .collect();
                match self.merge_pair(phase_id, dst_k, src_k, left, right, n_bands, &watch)? {
                    TreeFlow::Done(h) => {
                        self.gauge.track(hold_bytes(&h))?;
                        holders.insert(dst_k, h);
                    }
                    TreeFlow::Restart(r) => return Ok(TreeFlow::Restart(r)),
                }
            }
        }
        Ok(TreeFlow::Done(()))
    }

    /// Merge two holds into the span anchored at `dst`, band by band.
    /// Operands are named explicitly in `RMerge` frames (held key or wire
    /// `src`), so a worker's stale leaves from lost speculative executions
    /// can never leak into a sum.
    #[allow(clippy::too_many_arguments)]
    fn merge_pair(
        &mut self,
        phase_id: u64,
        dst: u32,
        src: u32,
        left: Hold,
        right: Hold,
        n_bands: usize,
        watch: &HashSet<usize>,
    ) -> Result<TreeFlow<Hold>> {
        match (left, right) {
            (Hold::Worker(a), Hold::Worker(b)) if a == b => {
                // Both spans held by one worker: merge in place.
                for band in 0..n_bands as u32 {
                    let msg = ToWorker::RMerge {
                        phase: phase_id,
                        dst_lo: dst,
                        band,
                        left_held: dst,
                        right_held: src,
                        src: Matrix::zeros(0, 0),
                    };
                    match self.relay_merge(phase_id, a, band, msg, watch)? {
                        TreeFlow::Done(()) => {}
                        TreeFlow::Restart(r) => return Ok(TreeFlow::Restart(r)),
                    }
                }
                Ok(TreeFlow::Done(Hold::Worker(a)))
            }
            (Hold::Worker(a), Hold::Worker(b)) => {
                // Relay: fetch each band from b, wire it into a's held sum.
                let mut watch2 = watch.clone();
                watch2.insert(a);
                watch2.insert(b);
                for band in 0..n_bands as u32 {
                    let fetch = ToWorker::RFetch {
                        phase: phase_id,
                        lo: src,
                        band,
                        what: FetchWhat::Partial,
                    };
                    if let Err(e) = self.send_worker(b, &fetch) {
                        self.mark_dead(b, &e.to_string());
                        return Ok(TreeFlow::Restart(format!("band fetch send failed: {e}")));
                    }
                    let m = match self.await_reduce(phase_id, b, src, band, &watch2)? {
                        TreeFlow::Done(ReduceReply::Part(m)) => m,
                        TreeFlow::Done(ReduceReply::Done) => {
                            return Err(Error::Other("expected band partial, got ack".into()));
                        }
                        TreeFlow::Restart(r) => return Ok(TreeFlow::Restart(r)),
                    };
                    let bytes = reduce::matrix_bytes(&m);
                    self.gauge.track(bytes)?;
                    let msg = ToWorker::RMerge {
                        phase: phase_id,
                        dst_lo: dst,
                        band,
                        left_held: dst,
                        right_held: HOLD_NONE,
                        src: m,
                    };
                    let flow = self.relay_merge(phase_id, a, band, msg, &watch2)?;
                    self.gauge.release(bytes);
                    match flow {
                        TreeFlow::Done(()) => {}
                        TreeFlow::Restart(r) => return Ok(TreeFlow::Restart(r)),
                    }
                }
                Ok(TreeFlow::Done(Hold::Worker(a)))
            }
            (Hold::Worker(a), Hold::Leader(bands)) => {
                // Leader-held span joins a's held sum over the wire. The
                // worker adds [held, wire] regardless of left/right naming;
                // elementwise f64 addition is bitwise commutative, so the
                // sum matches the schedule's bits either way.
                for (band, m) in bands.into_iter().enumerate() {
                    let msg = ToWorker::RMerge {
                        phase: phase_id,
                        dst_lo: dst,
                        band: band as u32,
                        left_held: dst,
                        right_held: HOLD_NONE,
                        src: m,
                    };
                    match self.relay_merge(phase_id, a, band as u32, msg, watch)? {
                        TreeFlow::Done(()) => {}
                        TreeFlow::Restart(r) => return Ok(TreeFlow::Restart(r)),
                    }
                }
                Ok(TreeFlow::Done(Hold::Worker(a)))
            }
            (Hold::Leader(bands), Hold::Worker(b)) => {
                for (band, m) in bands.into_iter().enumerate() {
                    let msg = ToWorker::RMerge {
                        phase: phase_id,
                        dst_lo: dst,
                        band: band as u32,
                        left_held: HOLD_NONE,
                        right_held: src,
                        src: m,
                    };
                    match self.relay_merge(phase_id, b, band as u32, msg, watch)? {
                        TreeFlow::Done(()) => {}
                        TreeFlow::Restart(r) => return Ok(TreeFlow::Restart(r)),
                    }
                }
                Ok(TreeFlow::Done(Hold::Worker(b)))
            }
            (Hold::Leader(lb), Hold::Leader(rb)) => {
                if lb.len() != rb.len() {
                    return Err(Error::Other(format!(
                        "band count mismatch in leader merge: {} vs {}",
                        lb.len(),
                        rb.len()
                    )));
                }
                let mut merged = Vec::with_capacity(lb.len());
                for (l, r) in lb.into_iter().zip(rb) {
                    merged.push(crate::splitproc::reduce_partials(vec![l, r])?);
                }
                Ok(TreeFlow::Done(Hold::Leader(merged)))
            }
        }
    }

    /// Send one `RMerge` to `target` and wait for its ack at
    /// `(dst key, band)` — the innermost step of every relayed merge.
    fn relay_merge(
        &mut self,
        phase_id: u64,
        target: usize,
        band: u32,
        msg: ToWorker,
        watch: &HashSet<usize>,
    ) -> Result<TreeFlow<()>> {
        let dst = match &msg {
            ToWorker::RMerge { dst_lo, .. } => *dst_lo,
            _ => return Err(Error::Other("relay_merge takes an RMerge".into())),
        };
        if let Err(e) = self.send_worker(target, &msg) {
            self.mark_dead(target, &e.to_string());
            return Ok(TreeFlow::Restart(format!("merge send to worker {target} failed: {e}")));
        }
        match self.await_reduce(phase_id, target, dst, band, watch)? {
            TreeFlow::Done(ReduceReply::Done) => Ok(TreeFlow::Done(())),
            TreeFlow::Done(ReduceReply::Part(_)) => {
                Err(Error::Other("expected merge ack, got matrix".into()))
            }
            TreeFlow::Restart(r) => Ok(TreeFlow::Restart(r)),
        }
    }

    /// Block until `target` answers for reduce key `(want_lo, want_band)`
    /// of `phase_id`, keeping liveness bookkeeping alive meanwhile: the
    /// target and every watched holder is fenced on staleness, their death
    /// aborts the attempt, stale frames from previous phases are ignored,
    /// and late joiners are registered (they idle until the next phase).
    fn await_reduce(
        &mut self,
        phase_id: u64,
        target: usize,
        want_lo: u32,
        want_band: u32,
        watch: &HashSet<usize>,
    ) -> Result<TreeFlow<ReduceReply>> {
        if !self.workers[target].alive {
            return Ok(TreeFlow::Restart(format!("worker {target} died before reduce step")));
        }
        let cutoff = Duration::from_millis(STALE_AFTER_MS);
        loop {
            for w in watch.iter().copied().chain(std::iter::once(target)) {
                if self.workers[w].alive && self.workers[w].last_seen.elapsed() > cutoff {
                    self.mark_dead(w, "silent during reduce");
                    return Ok(TreeFlow::Restart(format!(
                        "worker {w} silent during reduce: fenced"
                    )));
                }
            }
            match self.events.recv_timeout(Duration::from_millis(EVENT_POLL_MS)) {
                Ok(Event::Msg { worker: w, msg }) => {
                    self.workers[w].last_seen = Instant::now();
                    match msg {
                        ToLeader::Heartbeat | ToLeader::Hello { .. } => {}
                        // Straggler chunk acks from this or an older phase:
                        // clear the busy slot so the worker is assignable
                        // next phase; scheduling is long since settled.
                        ToLeader::ChunkDone { phase, chunk, .. }
                        | ToLeader::ChunkFailed { phase, chunk, .. } => {
                            if self.workers[w].busy == Some((phase, chunk)) {
                                self.workers[w].busy = None;
                            }
                        }
                        ToLeader::ReducePart { phase, lo, band, matrix } => {
                            if w == target
                                && phase == phase_id
                                && lo == want_lo
                                && band == want_band
                            {
                                return Ok(TreeFlow::Done(ReduceReply::Part(matrix)));
                            }
                        }
                        ToLeader::ReduceDone { phase, lo, band } => {
                            if w == target
                                && phase == phase_id
                                && lo == want_lo
                                && band == want_band
                            {
                                return Ok(TreeFlow::Done(ReduceReply::Done));
                            }
                        }
                        ToLeader::ReduceFailed { phase, lo, band, message } => {
                            if phase == phase_id {
                                return Ok(TreeFlow::Restart(format!(
                                    "worker {w} failed reduce step ({lo}, {band}): {message}"
                                )));
                            }
                        }
                    }
                }
                Ok(Event::Dead { worker: w, error }) => {
                    if self.workers[w].alive {
                        self.mark_dead(w, &error);
                        if w == target || watch.contains(&w) {
                            return Ok(TreeFlow::Restart(format!(
                                "worker {w} died mid-reduce: {error}"
                            )));
                        }
                    }
                }
                Ok(Event::Joined { stream, caps }) => {
                    match self.register(stream, caps) {
                        Ok(w) => LOG.info(&format!("worker {w} joined during reduce; idling")),
                        Err(e) => LOG.warn(&format!("failed to register joined worker: {e}")),
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Other("leader event channel closed".into()));
                }
            }
        }
    }

    fn release_holders(&mut self, holders: &HashMap<u32, Hold>) {
        let total: u64 = holders.values().map(hold_bytes).sum();
        self.gauge.release(total);
    }

    /// Drive one phase's chunks to completion. `hold` asks `CAP_HOLD`
    /// workers to keep their partial as held leaves (band height
    /// `band_rows`); their `ChunkDone` partials arrive empty. Restart
    /// (`TreeFlow::Restart`) means a holder was lost mid-drive.
    fn drive_chunks(
        &mut self,
        spec: &PhaseSpec,
        hold: bool,
        band_rows: u64,
    ) -> Result<TreeFlow<ChunkDrive>> {
        let mut d = ChunkDrive {
            phase_id: 0,
            rows: 0,
            partials: (0..spec.chunk_total).map(|_| None).collect(),
            holder_worker: vec![None; spec.chunk_total],
            tracked: 0,
            stats: None,
        };
        match self.drive_chunks_loop(spec, hold, band_rows, &mut d) {
            Ok(None) => Ok(TreeFlow::Done(d)),
            Ok(Some(reason)) => {
                self.gauge.release(d.tracked);
                Ok(TreeFlow::Restart(reason))
            }
            Err(e) => {
                self.gauge.release(d.tracked);
                Err(e)
            }
        }
    }

    fn drive_chunks_loop(
        &mut self,
        spec: &PhaseSpec,
        hold: bool,
        band_rows: u64,
        d: &mut ChunkDrive,
    ) -> Result<Option<String>> {
        let chunk_total = spec.chunk_total;
        if chunk_total == 0 {
            return Err(Error::Config("phase with zero chunks".into()));
        }
        self.next_phase += 1;
        let phase_id = self.next_phase;
        d.phase_id = phase_id;
        // Phase span on the leader's clock: chunk events merged from
        // worker reports parent under it, so one trace file holds the
        // whole cluster timeline (chunk ⊂ phase ⊂ run).
        let mut phase_span = Span::child(spec.kind.name(), "phase");
        phase_span.arg_str("executor", "cluster");
        phase_span.arg_num("chunks", chunk_total as f64);
        let phase_ctx = phase_span.ctx();
        if !phase_ctx.is_none() {
            for (w, worker) in self.workers.iter().enumerate() {
                trace::emit_global(&TraceEvent::thread_name(
                    WORKER_LANE_BASE + w as u64,
                    &format!("worker {w} ({})", worker.peer),
                ));
            }
        }
        let setup = ToWorker::Phase {
            id: phase_id,
            kind: spec.kind,
            input_path: spec.input.path.clone(),
            input_format: spec.input.format,
            work_dir: spec.work_dir.to_string(),
            chunk_total: chunk_total as u32,
            block: spec.block as u32,
            seed: spec.seed,
            kp: spec.kp as u32,
            cols: spec.cols as u32,
            shard_format: spec.shard_format,
            shard_epoch: spec.shard_epoch,
            operand: spec.operand.clone(),
            means: spec.means.clone(),
            trace: phase_ctx,
            hold,
            band_rows,
        };
        for w in 0..self.workers.len() {
            if self.workers[w].alive {
                if let Err(e) = send_to(&mut self.workers[w], &setup) {
                    LOG.warn(&format!("phase setup to worker {w} failed: {e}"));
                    self.workers[w].alive = false;
                    self.workers[w].busy = None;
                }
            }
        }
        // Staleness is judged within a pass: leader-side math between
        // passes can take arbitrarily long with no events drained, so every
        // worker gets a fresh grace period at pass start.
        for w in &mut self.workers {
            w.last_seen = Instant::now();
        }
        let sched = ChunkScheduler::new(chunk_total, spec.max_retries);
        let mut excluded: Vec<Vec<usize>> = vec![Vec::new(); chunk_total];
        let mut assigns: Vec<u32> = vec![0; chunk_total];
        for w in 0..self.workers.len() {
            self.assign_next(w, phase_id, phase_ctx, &sched, &mut excluded, &mut assigns);
        }
        while !sched.is_finished() {
            // Fence zombies every tick — even when other workers' events
            // (heartbeats) keep the channel busy, a worker silent past the
            // deadline must still lose its chunks.
            if let Some(reason) =
                self.fence_stale_workers(phase_id, &sched, &mut excluded, hold, d)
            {
                return Ok(Some(reason));
            }
            // Stalled? Nobody is executing anything (this phase or a stale
            // straggler that could free up) and nothing can be assigned.
            if !self.workers.iter().any(|w| w.alive && w.busy.is_some()) {
                for w in 0..self.workers.len() {
                    self.assign_next(w, phase_id, phase_ctx, &sched, &mut excluded, &mut assigns);
                }
                if !self.workers.iter().any(|w| w.alive && w.busy.is_some()) {
                    return Err(Error::Other(format!(
                        "{:?} pass stalled: {} of {chunk_total} chunks unfinished and no \
                         assignable live workers",
                        spec.kind,
                        sched.remaining()
                    )));
                }
            }
            match self.events.recv_timeout(Duration::from_millis(EVENT_POLL_MS)) {
                Ok(ev) => {
                    if let Some(reason) = self.handle_drive_event(
                        ev,
                        phase_id,
                        phase_ctx,
                        &setup,
                        &sched,
                        &mut excluded,
                        &mut assigns,
                        hold,
                        d,
                    )? {
                        return Ok(Some(reason));
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Other("leader event channel closed".into()));
                }
            }
            // Sweep idle workers after every event: a chunk requeued by
            // one worker's death must not wait for the *idle* workers to
            // produce an event of their own before it is handed out.
            if !sched.is_finished() {
                for w in 0..self.workers.len() {
                    self.assign_next(w, phase_id, phase_ctx, &sched, &mut excluded, &mut assigns);
                }
            }
        }
        d.stats = Some(sched.finish()?);
        Ok(None)
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_drive_event(
        &mut self,
        ev: Event,
        phase_id: u64,
        phase_ctx: TraceCtx,
        setup: &ToWorker,
        sched: &ChunkScheduler,
        excluded: &mut [Vec<usize>],
        assigns: &mut [u32],
        hold: bool,
        d: &mut ChunkDrive,
    ) -> Result<Option<String>> {
        match ev {
            Event::Msg { worker: w, msg } => {
                self.workers[w].last_seen = Instant::now();
                // A frame from a fenced worker proves the fence was wrong
                // (it was slow, not gone): resurrect it. Duplicates are
                // already safe, so the worst case is redundant work. It
                // may have missed this phase's setup broadcast while
                // fenced, so replay it before assigning — and clear the
                // exclusions the fence added, or the resurrected worker
                // stays barred from exactly the chunks it can still run.
                // (Replaying the *same* phase id does not clear the
                // worker's held leaves — only a new id does.)
                if !self.workers[w].alive {
                    LOG.warn(&format!("worker {w} reappeared after fencing: unfencing"));
                    self.workers[w].alive = true;
                    if send_to(&mut self.workers[w], setup).is_err() {
                        self.workers[w].alive = false;
                    } else {
                        for ex in excluded.iter_mut() {
                            ex.retain(|&x| x != w);
                        }
                    }
                }
                match msg {
                    ToLeader::Heartbeat | ToLeader::Hello { .. } => {}
                    // Stale acks from a reduce attempt this drive replaced.
                    ToLeader::ReducePart { .. }
                    | ToLeader::ReduceDone { .. }
                    | ToLeader::ReduceFailed { .. } => {}
                    ToLeader::ChunkDone {
                        phase,
                        chunk,
                        rows,
                        decode_us,
                        compute_us,
                        encode_us,
                        partial,
                    } => {
                        // Only the execution the leader is tracking counts
                        // — and only it clears the busy slot: a report for
                        // an assignment the fence already released must
                        // neither touch the scheduler nor wipe the
                        // tracking of a newer assignment queued behind it.
                        let tracked = self.workers[w].busy == Some((phase, chunk));
                        if tracked {
                            let elapsed = self.workers[w].busy_since.elapsed();
                            self.workers[w].busy = None;
                            // Merge this execution into the leader's
                            // timeline: one X event per completed
                            // execution, back-dated on the leader's clock,
                            // on the worker's own lane.
                            if !phase_ctx.is_none() && phase == phase_id {
                                self.emit_chunk_event(
                                    w,
                                    phase_ctx,
                                    chunk,
                                    elapsed,
                                    (decode_us, compute_us, encode_us),
                                );
                            }
                            if phase == phase_id && (chunk as usize) < d.partials.len() {
                                // First completion wins; a duplicate's
                                // result is dropped (its shard bytes are
                                // identical, and a duplicate holder's stale
                                // leaves are never named by merge frames).
                                if sched.complete(chunk as usize, elapsed) {
                                    d.rows += rows;
                                    let c = chunk as usize;
                                    if hold && partial.rows() == 0 {
                                        d.holder_worker[c] = Some(w);
                                    } else if partial.rows() > 0 {
                                        let bytes = reduce::matrix_bytes(&partial);
                                        self.gauge.track(bytes)?;
                                        d.tracked += bytes;
                                        d.partials[c] = Some(partial);
                                    }
                                }
                            }
                        }
                        self.assign_next(w, phase_id, phase_ctx, sched, excluded, assigns);
                    }
                    ToLeader::ChunkFailed { phase, chunk, message } => {
                        let tracked = self.workers[w].busy == Some((phase, chunk));
                        if tracked {
                            self.workers[w].busy = None;
                            if phase == phase_id && (chunk as usize) < d.partials.len() {
                                LOG.warn(&format!("worker {w} failed chunk {chunk}: {message}"));
                                sched.fail(
                                    chunk as usize,
                                    Error::Other(format!("worker {w}: {message}")),
                                );
                            }
                        }
                        self.assign_next(w, phase_id, phase_ctx, sched, excluded, assigns);
                    }
                }
            }
            Event::Dead { worker: w, error } => {
                if self.workers[w].alive {
                    LOG.warn(&format!("worker {w} died: {error}"));
                    self.workers[w].alive = false;
                    if let Some((ph, c)) = self.workers[w].busy.take() {
                        if ph == phase_id {
                            // Requeue its in-flight chunk, excluding the
                            // dead worker (it may reconnect as a new id).
                            excluded[c as usize].push(w);
                            sched.release(c as usize);
                        }
                    }
                    // A dead holder takes its leaves with it: the attempt
                    // restarts (chunk re-execution is deterministic).
                    if hold && d.holder_worker.iter().any(|h| *h == Some(w)) {
                        return Ok(Some(format!("worker {w} died holding reduce leaves: {error}")));
                    }
                }
            }
            Event::Joined { stream, caps } => match self.register(stream, caps) {
                Ok(w) => {
                    LOG.info(&format!("worker {w} joined mid-run"));
                    if !phase_ctx.is_none() {
                        trace::emit_global(&TraceEvent::thread_name(
                            WORKER_LANE_BASE + w as u64,
                            &format!("worker {w} ({})", self.workers[w].peer),
                        ));
                    }
                    if let Err(e) = send_to(&mut self.workers[w], setup) {
                        LOG.warn(&format!("phase setup to joined worker {w} failed: {e}"));
                        self.workers[w].alive = false;
                    } else {
                        self.assign_next(w, phase_id, phase_ctx, sched, excluded, assigns);
                    }
                }
                Err(e) => LOG.warn(&format!("failed to register joined worker: {e}")),
            },
        }
        Ok(None)
    }

    /// Hand the next chunk to an idle worker: a queued chunk it isn't
    /// excluded from, or — once the queue is dry — a speculative duplicate
    /// of the longest-running chunk on some *other* worker.
    fn assign_next(
        &mut self,
        w: usize,
        phase_id: u64,
        phase_ctx: TraceCtx,
        sched: &ChunkScheduler,
        excluded: &mut [Vec<usize>],
        assigns: &mut [u32],
    ) {
        if !self.workers[w].alive || self.workers[w].busy.is_some() || sched.is_finished() {
            return;
        }
        let mut speculative = false;
        let pick = match sched.try_claim(|c| !excluded[c].contains(&w)) {
            Some(c) => Some(c),
            None => {
                let mut best: Option<(usize, Instant)> = None;
                for c in sched.running_chunks() {
                    if excluded[c].contains(&w) {
                        continue;
                    }
                    let runners: Vec<usize> = self
                        .workers
                        .iter()
                        .enumerate()
                        .filter(|(_, wk)| wk.alive && wk.busy == Some((phase_id, c as u32)))
                        .map(|(i, _)| i)
                        .collect();
                    // Duplicate only chunks running on exactly one other
                    // worker (no speculation pile-ups).
                    if runners.len() == 1 && runners[0] != w {
                        let since = self.workers[runners[0]].busy_since;
                        let longer_running = match best {
                            None => true,
                            Some((_, b)) => since < b,
                        };
                        if longer_running {
                            best = Some((c, since));
                        }
                    }
                }
                best.map(|(c, _)| {
                    sched.speculate(c);
                    speculative = true;
                    c
                })
            }
        };
        let Some(c) = pick else { return };
        // Per-assignment span context: the worker adopts it (logs + its
        // local chunk span), and the leader's merged timeline event reuses
        // the same span id, so both sides name one execution identically.
        let actx = if phase_ctx.is_none() {
            TraceCtx::NONE
        } else {
            TraceCtx { trace: phase_ctx.trace, span: next_id() }
        };
        let msg = ToWorker::Assign { phase: phase_id, chunk: c as u32, trace: actx };
        match send_to(&mut self.workers[w], &msg) {
            Ok(()) => {
                self.workers[w].busy = Some((phase_id, c as u32));
                self.workers[w].busy_since = Instant::now();
                self.workers[w].assign_span = actx.span;
                self.workers[w].assign_retry = assigns[c] > 0 && !speculative;
                self.workers[w].assign_speculative = speculative;
                assigns[c] += 1;
            }
            Err(e) => {
                LOG.warn(&format!("assign chunk {c} to worker {w} failed: {e}"));
                self.workers[w].alive = false;
                excluded[c].push(w);
                sched.release(c);
            }
        }
    }

    /// Emit the merged timeline event for one completed chunk execution:
    /// back-dated from the measured elapsed time so it sits on the
    /// leader's trace clock, on the worker's own lane, tagged with the
    /// worker's decode/compute/encode split off the `ChunkDone` frame.
    fn emit_chunk_event(
        &self,
        w: usize,
        phase_ctx: TraceCtx,
        chunk: u32,
        elapsed: Duration,
        sections_us: (u64, u64, u64),
    ) {
        let Some(now_us) = trace::global_now_us() else { return };
        let elapsed_us = elapsed.as_micros() as u64;
        let worker = &self.workers[w];
        let (decode_us, compute_us, encode_us) = sections_us;
        let ev = TraceEvent::complete(
            &format!("chunk {chunk}"),
            "chunk",
            now_us.saturating_sub(elapsed_us),
            elapsed_us,
            WORKER_LANE_BASE + w as u64,
        )
        .arg_str("trace", &format!("{:016x}", phase_ctx.trace))
        .arg_str("span", &format!("{:016x}", worker.assign_span))
        .arg_str("parent", &format!("{:016x}", phase_ctx.span))
        .arg_str("worker", &worker.peer)
        .arg_num("chunk", chunk as f64)
        .arg_num("decode_ms", decode_us as f64 / 1e3)
        .arg_num("compute_ms", compute_us as f64 / 1e3)
        .arg_num("encode_ms", encode_us as f64 / 1e3)
        .arg_bool("retry", worker.assign_retry)
        .arg_bool("speculative", worker.assign_speculative);
        trace::emit_global(&ev);
    }

    /// Fence workers silent past [`STALE_AFTER_MS`]: mark dead, requeue
    /// their in-flight chunks. Runs on event-loop idle ticks. In hold mode
    /// a fenced holder aborts the attempt (its leaves are unreachable).
    fn fence_stale_workers(
        &mut self,
        phase_id: u64,
        sched: &ChunkScheduler,
        excluded: &mut [Vec<usize>],
        hold: bool,
        d: &ChunkDrive,
    ) -> Option<String> {
        let cutoff = Duration::from_millis(STALE_AFTER_MS);
        for w in 0..self.workers.len() {
            if self.workers[w].alive && self.workers[w].last_seen.elapsed() > cutoff {
                LOG.warn(&format!(
                    "worker {w} silent for {:.1}s: fencing",
                    self.workers[w].last_seen.elapsed().as_secs_f64()
                ));
                self.workers[w].alive = false;
                if let Some((ph, c)) = self.workers[w].busy.take() {
                    if ph == phase_id {
                        excluded[c as usize].push(w);
                        sched.release(c as usize);
                    }
                }
                if hold && d.holder_worker.iter().any(|h| *h == Some(w)) {
                    return Some(format!("worker {w} fenced while holding reduce leaves"));
                }
            }
        }
        None
    }

    /// Tell every still-connected worker to exit (fenced ones included —
    /// they may merely have been slow) and stop accepting joiners. A dead
    /// connection must not stop the others from being told; only failures
    /// to live workers are reported.
    pub fn shutdown(&mut self) -> Result<()> {
        self.stop_accept.store(true, Ordering::Relaxed);
        // Wake the accept thread so it observes the stop flag.
        let _ = TcpStream::connect(&self.listen_addr);
        let mut failure: Option<Error> = None;
        for i in 0..self.workers.len() {
            let was_alive = self.workers[i].alive;
            if let Err(e) = send_to(&mut self.workers[i], &ToWorker::Shutdown) {
                if was_alive && failure.is_none() {
                    failure = Some(Error::Other(format!("shutdown of worker {i} failed: {e}")));
                }
            }
        }
        match failure {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}
