//! The leader: accepts worker connections, drives the phase schedule of the
//! randomized SVD across them, reduces partials, owns the small dense math.

use super::proto::{PhaseKind, ToLeader, ToWorker, VERSION};
use crate::backend::BackendRef;
use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::writer::ShardSet;
use crate::io::InputSpec;
use crate::linalg::{matmul, Matrix};
use crate::metrics::PhaseReport;
use crate::splitproc;
use crate::svd::{SvdOptions, SvdResult};
use crate::util::Logger;
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

static LOG: Logger = Logger::new("cluster.leader");

/// Distributed-run options on top of [`SvdOptions`].
#[derive(Clone, Debug)]
pub struct DistOptions {
    /// Listen address, e.g. `127.0.0.1:7070`.
    pub listen: String,
    /// Number of remote workers to wait for (= chunk count).
    pub workers: usize,
}

/// One connected worker.
struct WorkerConn {
    stream: TcpStream,
}

impl WorkerConn {
    fn send(&mut self, msg: &ToWorker) -> Result<()> {
        msg.write(&mut self.stream)
    }

    fn recv(&mut self) -> Result<ToLeader> {
        ToLeader::read(&mut self.stream)
    }
}

/// Accepts workers, runs phases, reduces partials.
pub struct DistributedLeader {
    workers: Vec<WorkerConn>,
}

impl DistributedLeader {
    /// Bind `listen` and wait for exactly `n` workers to say hello.
    pub fn accept(listen: &str, n: usize) -> Result<Self> {
        if n == 0 {
            return Err(Error::Config("remote-workers must be >= 1".into()));
        }
        let listener = TcpListener::bind(listen)?;
        LOG.info(&format!("leader on {listen}, waiting for {n} workers"));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let (stream, peer) = listener.accept()?;
            stream.set_nodelay(true).ok();
            let mut conn = WorkerConn { stream };
            match conn.recv()? {
                ToLeader::Hello { version } if version == VERSION => {
                    LOG.info(&format!("worker {i} joined from {peer}"));
                    workers.push(conn);
                }
                ToLeader::Hello { version } => {
                    return Err(Error::Config(format!(
                        "worker {peer} speaks protocol v{version}, leader v{VERSION}"
                    )));
                }
                other => {
                    return Err(Error::parse(format!("expected hello, got {other:?}")));
                }
            }
        }
        Ok(DistributedLeader { workers })
    }

    /// Number of connected workers.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Run one phase on all workers (worker i gets chunk i) and collect
    /// `(total_rows, partials)`.
    #[allow(clippy::too_many_arguments)]
    pub fn run_phase(
        &mut self,
        kind: PhaseKind,
        input: &InputSpec,
        work_dir: &str,
        block: usize,
        seed: u64,
        kp: usize,
        operand: &Matrix,
    ) -> Result<(u64, Vec<Matrix>)> {
        let total = self.workers.len() as u32;
        for (i, w) in self.workers.iter_mut().enumerate() {
            w.send(&ToWorker::Phase {
                kind,
                input_path: input.path.clone(),
                work_dir: work_dir.to_string(),
                chunk_index: i as u32,
                chunk_total: total,
                block: block as u32,
                seed,
                kp: kp as u32,
                operand: operand.clone(),
            })?;
        }
        let mut rows = 0u64;
        let mut partials = Vec::with_capacity(self.workers.len());
        for (i, w) in self.workers.iter_mut().enumerate() {
            match w.recv()? {
                ToLeader::Partial { rows: r, partial } => {
                    rows += r;
                    if partial.rows() > 0 {
                        partials.push(partial);
                    }
                }
                ToLeader::Failed { message } => {
                    return Err(Error::Other(format!("worker {i} failed: {message}")));
                }
                other => return Err(Error::parse(format!("unexpected reply: {other:?}"))),
            }
        }
        Ok((rows, partials))
    }

    /// Tell every worker to exit.
    pub fn shutdown(&mut self) -> Result<()> {
        for w in &mut self.workers {
            w.send(&ToWorker::Shutdown)?;
        }
        Ok(())
    }
}

fn guarded_inverse(sigma: &[f64], cutoff_rel: f64) -> Vec<f64> {
    let smax = sigma.first().copied().unwrap_or(0.0).max(1e-300);
    sigma
        .iter()
        .map(|&s| if s > cutoff_rel * smax { 1.0 / s } else { 0.0 })
        .collect()
}

/// The randomized SVD with every streaming pass delegated to remote
/// workers. The leader computes only the `k' x k'` eigensolves and the
/// `n x k'` orthonormalization — the paper's "fast computation around
/// k x k matrices computed on a single machine", now literally on one
/// machine while the passes run on N others.
pub fn distributed_randomized_svd(
    leader: &mut DistributedLeader,
    input: &InputSpec,
    backend: BackendRef, // leader-side math only
    opts: &SvdOptions,
) -> Result<SvdResult> {
    let mut report = PhaseReport::new();
    let (m_rows, n) = input.dims()?;
    if m_rows == 0 || n == 0 {
        return Err(Error::Config("empty input matrix".into()));
    }
    let kp = (opts.k + opts.oversample).min(n).min(m_rows);
    let shards_count = leader.worker_count();
    LOG.info(&format!(
        "distributed svd: {m_rows}x{n} -> k={} (sketch {kp}) across {shards_count} workers",
        opts.k.min(kp)
    ));
    std::fs::create_dir_all(&opts.work_dir)?;
    let empty = Matrix::zeros(0, 0);

    // Power-iteration loop mirrors svd::pipeline::randomized_svd_file.
    let mut omega_override = empty.clone();
    let mut w_mat;
    let mut iteration = 0usize;
    loop {
        // ---- pass 1 (remote): Y = A Ω, G = Σ YᵀY -------------------------
        let t0 = Instant::now();
        let (rows, partials) = leader.run_phase(
            PhaseKind::ProjectGram,
            input,
            &opts.work_dir,
            opts.block,
            opts.seed,
            kp,
            &omega_override,
        )?;
        if rows as usize != m_rows {
            return Err(Error::Other(format!("pass1 saw {rows} rows, expected {m_rows}")));
        }
        let g = splitproc::reduce_partials(partials)?;
        report.push(&format!("pass1.remote[{iteration}]"), t0.elapsed(), rows, 0);

        // ---- leader: eigh(G), M = V_y Σ_y⁻¹ ------------------------------
        let t0 = Instant::now();
        let (w_eig, v_y) = backend.eigh(&g)?;
        let sig_y: Vec<f64> = w_eig.iter().map(|&w| w.max(0.0).sqrt()).collect();
        let inv_y = guarded_inverse(&sig_y, 1e-7);
        let m_mat = v_y.scale_cols(&inv_y)?;
        report.push(&format!("leader.eigh_y[{iteration}]"), t0.elapsed(), kp as u64, 0);

        // ---- pass 2 (remote): U0 = Y M, W = Σ Aᵀ U0 ----------------------
        let t0 = Instant::now();
        let (rows2, w_partials) = leader.run_phase(
            PhaseKind::UrecoverTmul,
            input,
            &opts.work_dir,
            opts.block,
            opts.seed,
            kp,
            &m_mat,
        )?;
        w_mat = splitproc::reduce_partials(w_partials)?;
        report.push(&format!("pass2.remote[{iteration}]"), t0.elapsed(), rows2, 0);

        if iteration >= opts.power_iters {
            break;
        }
        let t0 = Instant::now();
        let (q, _) = crate::linalg::thin_qr(&w_mat)?;
        omega_override = q;
        iteration += 1;
        report.push(&format!("leader.power_orth[{iteration}]"), t0.elapsed(), 0, 0);
    }

    // ---- leader: small SVD completion --------------------------------------
    let t0 = Instant::now();
    let gw = backend.gram_block(&w_mat)?;
    let (w2, p) = backend.eigh(&gw)?;
    let sigma_full: Vec<f64> = w2.iter().map(|&w| w.max(0.0).sqrt()).collect();
    let k = opts.k.min(kp);
    let sigma: Vec<f64> = sigma_full[..k].to_vec();
    let p_k = p.slice_cols(0, k);
    let v = if opts.compute_v {
        let inv_s = guarded_inverse(&sigma, 1e-12);
        let vp = matmul(&w_mat, &p_k)?;
        Some(vp.scale_cols(&inv_s)?)
    } else {
        None
    };
    report.push("leader.eigh_w", t0.elapsed(), kp as u64, 0);

    // ---- pass 3 (remote): U = U0 P ------------------------------------------
    let t0 = Instant::now();
    let (rows3, _) = leader.run_phase(
        PhaseKind::RotateU,
        input,
        &opts.work_dir,
        opts.block,
        opts.seed,
        k,
        &p_k,
    )?;
    report.push("pass3.remote", t0.elapsed(), rows3, 0);

    let u_shards = ShardSet::new(&opts.work_dir, "U", InputFormat::Bin)?;
    LOG.info(&format!(
        "distributed svd done: sigma[0]={:.4}",
        sigma.first().copied().unwrap_or(0.0)
    ));
    Ok(SvdResult {
        m: m_rows,
        n,
        k,
        sigma,
        v,
        u_shards,
        shards: shards_count,
        means: None,
        report,
    })
}
