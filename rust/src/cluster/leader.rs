//! The leader: accepts worker connections, streams chunk assignments to
//! them, and collects per-chunk acks. The SVD math itself lives in
//! [`crate::svd::pipeline`] — this module is transport plus the cluster
//! side of the chunk scheduler, driven through
//! [`crate::cluster::ClusterExecutor`].
//!
//! One recv thread per worker turns every connection into an event stream
//! (`ChunkDone` / `ChunkFailed` / `Heartbeat` / death); the leader's event
//! loop feeds a [`ChunkScheduler`]:
//!
//! * a worker finishing a chunk immediately gets the next queued chunk —
//!   fast workers drain the queue, slow ones don't gate it;
//! * a worker dying mid-chunk requeues its chunk with that worker
//!   excluded, and a worker silent past [`STALE_AFTER_MS`] (no heartbeat)
//!   is fenced the same way;
//! * a worker connecting mid-run (the background accept loop keeps the
//!   listen socket open) is sent the current phase setup and starts
//!   pulling queued chunks;
//! * once the queue drains, idle workers speculatively re-execute the
//!   longest-running chunks; the first completion wins, duplicates are
//!   dropped (shard writes are staged + atomically renamed, so a late
//!   duplicate is harmless).

use super::proto::{PhaseKind, ToLeader, ToWorker, VERSION};
use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::InputSpec;
use crate::linalg::Matrix;
use crate::obs::trace::{self, next_id, Span, TraceCtx, TraceEvent};
use crate::splitproc::{ChunkScheduler, SchedStats};
use crate::util::Logger;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

static LOG: Logger = Logger::new("cluster.leader");

/// A worker silent for this long (no frame, no heartbeat — the heartbeat
/// period is [`super::worker::HEARTBEAT_MS`]) is treated as dead and its
/// in-flight chunk requeued.
pub const STALE_AFTER_MS: u64 = 10_000;

/// Event-loop poll period when no events arrive (drives the staleness
/// sweep).
const EVENT_POLL_MS: u64 = 1_000;

/// Trace lane for merged worker chunk events: lane = base + worker index.
/// Kept clear of the leader's own small per-thread lane ids.
const WORKER_LANE_BASE: u64 = 100;

/// One connected worker, leader-side: the write half of its socket plus
/// scheduling state. The read half lives in its recv thread.
struct Worker {
    stream: TcpStream,
    /// Peer address, for logs and trace attribution.
    peer: String,
    alive: bool,
    /// The `(phase, chunk)` assignment in flight, if any (workers execute
    /// one chunk at a time).
    busy: Option<(u64, u32)>,
    busy_since: Instant,
    last_seen: Instant,
    /// Span id of the in-flight assignment (0 when the run isn't traced);
    /// the merged timeline event for the chunk reuses it, so the worker's
    /// logs and the leader's event carry the same span.
    assign_span: u64,
    /// The in-flight assignment re-runs a chunk that was assigned before
    /// (failure retry or death requeue).
    assign_retry: bool,
    /// The in-flight assignment is a speculative duplicate.
    assign_speculative: bool,
}

enum Event {
    Msg { worker: usize, msg: ToLeader },
    Dead { worker: usize, error: String },
    Joined { stream: TcpStream },
}

fn send_to(worker: &mut Worker, msg: &ToWorker) -> Result<()> {
    let mut stream: &TcpStream = &worker.stream;
    msg.write(&mut stream)
}

fn recv_loop(mut reader: TcpStream, id: usize, tx: Sender<Event>) {
    loop {
        match ToLeader::read(&mut reader) {
            Ok(msg) => {
                if tx.send(Event::Msg { worker: id, msg }).is_err() {
                    return;
                }
            }
            Err(e) => {
                let _ = tx.send(Event::Dead { worker: id, error: e.to_string() });
                return;
            }
        }
    }
}

fn accept_loop(listener: TcpListener, tx: Sender<Event>, stop: Arc<AtomicBool>) {
    loop {
        let accepted = listener.accept();
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let Ok((stream, peer)) = accepted else { continue };
        stream.set_nodelay(true).ok();
        // Bound the hello wait so a rogue silent connection can't wedge
        // late joins forever.
        stream.set_read_timeout(Some(Duration::from_secs(2))).ok();
        let hello = {
            let mut rs: &TcpStream = &stream;
            ToLeader::read(&mut rs)
        };
        match hello {
            Ok(ToLeader::Hello { version }) if version == VERSION => {
                stream.set_read_timeout(None).ok();
                LOG.info(&format!("late worker from {peer} verified"));
                if tx.send(Event::Joined { stream }).is_err() {
                    return;
                }
            }
            Ok(ToLeader::Hello { version }) => {
                LOG.warn(&format!("rejected {peer}: protocol v{version}, leader v{VERSION}"));
            }
            Ok(other) => {
                LOG.warn(&format!("rejected {peer}: expected hello, got {other:?}"));
            }
            Err(e) => {
                LOG.warn(&format!("rejected {peer}: {e}"));
            }
        }
    }
}

/// Accepts workers, schedules chunk-grained phases, reduces partials.
pub struct DistributedLeader {
    workers: Vec<Worker>,
    events: Receiver<Event>,
    events_tx: Sender<Event>,
    listen_addr: String,
    stop_accept: Arc<AtomicBool>,
    next_phase: u64,
}

impl DistributedLeader {
    /// Bind `listen` and wait for exactly `n` workers to say hello; the
    /// listen socket then stays open in the background so more workers can
    /// join any later pass mid-run.
    pub fn accept(listen: &str, n: usize) -> Result<Self> {
        if n == 0 {
            return Err(Error::Config("remote-workers must be >= 1".into()));
        }
        let listener = TcpListener::bind(listen)?;
        let listen_addr = listener.local_addr()?.to_string();
        LOG.info(&format!("leader on {listen_addr}, waiting for {n} workers"));
        let (events_tx, events) = mpsc::channel();
        let mut leader = DistributedLeader {
            workers: Vec::new(),
            events,
            events_tx,
            listen_addr,
            stop_accept: Arc::new(AtomicBool::new(false)),
            next_phase: 0,
        };
        for i in 0..n {
            let (stream, peer) = listener.accept()?;
            stream.set_nodelay(true).ok();
            let hello = {
                let mut rs: &TcpStream = &stream;
                ToLeader::read(&mut rs)?
            };
            match hello {
                ToLeader::Hello { version } if version == VERSION => {
                    LOG.info(&format!("worker {i} joined from {peer}"));
                    leader.register(stream)?;
                }
                ToLeader::Hello { version } => {
                    return Err(Error::Config(format!(
                        "worker {peer} speaks protocol v{version}, leader v{VERSION}"
                    )));
                }
                other => {
                    return Err(Error::parse(format!("expected hello, got {other:?}")));
                }
            }
        }
        let tx = leader.events_tx.clone();
        let stop = leader.stop_accept.clone();
        std::thread::spawn(move || accept_loop(listener, tx, stop));
        Ok(leader)
    }

    /// Add a verified worker connection: spawn its recv thread, track its
    /// write half. The hello must already have been consumed.
    fn register(&mut self, stream: TcpStream) -> Result<usize> {
        let id = self.workers.len();
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| format!("worker-{id}"));
        let reader = stream.try_clone()?;
        let tx = self.events_tx.clone();
        std::thread::spawn(move || recv_loop(reader, id, tx));
        self.workers.push(Worker {
            stream,
            peer,
            alive: true,
            busy: None,
            busy_since: Instant::now(),
            last_seen: Instant::now(),
            assign_span: 0,
            assign_retry: false,
            assign_speculative: false,
        });
        Ok(id)
    }

    /// Number of live workers.
    pub fn worker_count(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).count()
    }

    /// Run one phase: broadcast the setup, stream `chunk_total` chunk
    /// assignments through the scheduler (retry budget `max_retries` per
    /// chunk), and collect `(total_rows, partials_in_chunk_order, stats)`.
    #[allow(clippy::too_many_arguments)]
    pub fn run_phase(
        &mut self,
        kind: PhaseKind,
        input: &InputSpec,
        work_dir: &str,
        block: usize,
        seed: u64,
        kp: usize,
        cols: usize,
        shard_format: InputFormat,
        shard_epoch: u32,
        operand: &Matrix,
        means: &Matrix,
        chunk_total: usize,
        max_retries: usize,
    ) -> Result<(u64, Vec<Matrix>, SchedStats)> {
        if chunk_total == 0 {
            return Err(Error::Config("phase with zero chunks".into()));
        }
        self.next_phase += 1;
        let phase_id = self.next_phase;
        // Phase span on the leader's clock: chunk events merged from
        // worker reports parent under it, so one trace file holds the
        // whole cluster timeline (chunk ⊂ phase ⊂ run).
        let mut phase_span = Span::child(kind.name(), "phase");
        phase_span.arg_str("executor", "cluster");
        phase_span.arg_num("chunks", chunk_total as f64);
        let phase_ctx = phase_span.ctx();
        if !phase_ctx.is_none() {
            for (w, worker) in self.workers.iter().enumerate() {
                trace::emit_global(&TraceEvent::thread_name(
                    WORKER_LANE_BASE + w as u64,
                    &format!("worker {w} ({})", worker.peer),
                ));
            }
        }
        let setup = ToWorker::Phase {
            id: phase_id,
            kind,
            input_path: input.path.clone(),
            input_format: input.format,
            work_dir: work_dir.to_string(),
            chunk_total: chunk_total as u32,
            block: block as u32,
            seed,
            kp: kp as u32,
            cols: cols as u32,
            shard_format,
            shard_epoch,
            operand: operand.clone(),
            means: means.clone(),
            trace: phase_ctx,
        };
        for w in 0..self.workers.len() {
            if self.workers[w].alive {
                if let Err(e) = send_to(&mut self.workers[w], &setup) {
                    LOG.warn(&format!("phase setup to worker {w} failed: {e}"));
                    self.workers[w].alive = false;
                    self.workers[w].busy = None;
                }
            }
        }
        // Staleness is judged within a pass: leader-side math between
        // passes can take arbitrarily long with no events drained, so every
        // worker gets a fresh grace period at pass start.
        for w in &mut self.workers {
            w.last_seen = Instant::now();
        }
        let sched = ChunkScheduler::new(chunk_total, max_retries);
        let mut excluded: Vec<Vec<usize>> = vec![Vec::new(); chunk_total];
        let mut assigns: Vec<u32> = vec![0; chunk_total];
        let mut rows_total = 0u64;
        let mut partials: Vec<Option<Matrix>> = (0..chunk_total).map(|_| None).collect();
        for w in 0..self.workers.len() {
            self.assign_next(w, phase_id, phase_ctx, &sched, &mut excluded, &mut assigns);
        }
        while !sched.is_finished() {
            // Fence zombies every tick — even when other workers' events
            // (heartbeats) keep the channel busy, a worker silent past the
            // deadline must still lose its chunks.
            self.fence_stale_workers(phase_id, &sched, &mut excluded);
            // Stalled? Nobody is executing anything (this phase or a stale
            // straggler that could free up) and nothing can be assigned.
            if !self.workers.iter().any(|w| w.alive && w.busy.is_some()) {
                for w in 0..self.workers.len() {
                    self.assign_next(w, phase_id, phase_ctx, &sched, &mut excluded, &mut assigns);
                }
                if !self.workers.iter().any(|w| w.alive && w.busy.is_some()) {
                    return Err(Error::Other(format!(
                        "{:?} pass stalled: {} of {chunk_total} chunks unfinished and no \
                         assignable live workers",
                        kind,
                        sched.remaining()
                    )));
                }
            }
            match self.events.recv_timeout(Duration::from_millis(EVENT_POLL_MS)) {
                Ok(ev) => self.handle_event(
                    ev,
                    phase_id,
                    phase_ctx,
                    &setup,
                    &sched,
                    &mut excluded,
                    &mut assigns,
                    &mut rows_total,
                    &mut partials,
                ),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Other("leader event channel closed".into()));
                }
            }
            // Sweep idle workers after every event: a chunk requeued by
            // one worker's death must not wait for the *idle* workers to
            // produce an event of their own before it is handed out.
            if !sched.is_finished() {
                for w in 0..self.workers.len() {
                    self.assign_next(w, phase_id, phase_ctx, &sched, &mut excluded, &mut assigns);
                }
            }
        }
        let stats = sched.finish()?;
        let ordered: Vec<Matrix> = partials.into_iter().flatten().collect();
        Ok((rows_total, ordered, stats))
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_event(
        &mut self,
        ev: Event,
        phase_id: u64,
        phase_ctx: TraceCtx,
        setup: &ToWorker,
        sched: &ChunkScheduler,
        excluded: &mut [Vec<usize>],
        assigns: &mut [u32],
        rows_total: &mut u64,
        partials: &mut [Option<Matrix>],
    ) {
        match ev {
            Event::Msg { worker: w, msg } => {
                self.workers[w].last_seen = Instant::now();
                // A frame from a fenced worker proves the fence was wrong
                // (it was slow, not gone): resurrect it. Duplicates are
                // already safe, so the worst case is redundant work. It
                // may have missed this phase's setup broadcast while
                // fenced, so replay it before assigning — and clear the
                // exclusions the fence added, or the resurrected worker
                // stays barred from exactly the chunks it can still run.
                if !self.workers[w].alive {
                    LOG.warn(&format!("worker {w} reappeared after fencing: unfencing"));
                    self.workers[w].alive = true;
                    if send_to(&mut self.workers[w], setup).is_err() {
                        self.workers[w].alive = false;
                    } else {
                        for ex in excluded.iter_mut() {
                            ex.retain(|&x| x != w);
                        }
                    }
                }
                match msg {
                    ToLeader::Heartbeat | ToLeader::Hello { .. } => {}
                    ToLeader::ChunkDone {
                        phase,
                        chunk,
                        rows,
                        decode_us,
                        compute_us,
                        encode_us,
                        partial,
                    } => {
                        // Only the execution the leader is tracking counts
                        // — and only it clears the busy slot: a report for
                        // an assignment the fence already released must
                        // neither touch the scheduler nor wipe the
                        // tracking of a newer assignment queued behind it.
                        let tracked = self.workers[w].busy == Some((phase, chunk));
                        if tracked {
                            let elapsed = self.workers[w].busy_since.elapsed();
                            self.workers[w].busy = None;
                            // Merge this execution into the leader's
                            // timeline: one X event per completed
                            // execution, back-dated on the leader's clock,
                            // on the worker's own lane.
                            if !phase_ctx.is_none() && phase == phase_id {
                                self.emit_chunk_event(
                                    w,
                                    phase_ctx,
                                    chunk,
                                    elapsed,
                                    (decode_us, compute_us, encode_us),
                                );
                            }
                            if phase == phase_id && (chunk as usize) < partials.len() {
                                // First completion wins; a duplicate's
                                // result is dropped (its shard bytes are
                                // identical).
                                if sched.complete(chunk as usize, elapsed) {
                                    *rows_total += rows;
                                    if partial.rows() > 0 {
                                        partials[chunk as usize] = Some(partial);
                                    }
                                }
                            }
                        }
                        self.assign_next(w, phase_id, phase_ctx, sched, excluded, assigns);
                    }
                    ToLeader::ChunkFailed { phase, chunk, message } => {
                        let tracked = self.workers[w].busy == Some((phase, chunk));
                        if tracked {
                            self.workers[w].busy = None;
                            if phase == phase_id && (chunk as usize) < partials.len() {
                                LOG.warn(&format!(
                                    "worker {w} failed chunk {chunk}: {message}"
                                ));
                                sched.fail(
                                    chunk as usize,
                                    Error::Other(format!("worker {w}: {message}")),
                                );
                            }
                        }
                        self.assign_next(w, phase_id, phase_ctx, sched, excluded, assigns);
                    }
                }
            }
            Event::Dead { worker: w, error } => {
                if self.workers[w].alive {
                    LOG.warn(&format!("worker {w} died: {error}"));
                    self.workers[w].alive = false;
                    if let Some((ph, c)) = self.workers[w].busy.take() {
                        if ph == phase_id {
                            // Requeue its in-flight chunk, excluding the
                            // dead worker (it may reconnect as a new id).
                            excluded[c as usize].push(w);
                            sched.release(c as usize);
                        }
                    }
                }
            }
            Event::Joined { stream } => match self.register(stream) {
                Ok(w) => {
                    LOG.info(&format!("worker {w} joined mid-run"));
                    if !phase_ctx.is_none() {
                        trace::emit_global(&TraceEvent::thread_name(
                            WORKER_LANE_BASE + w as u64,
                            &format!("worker {w} ({})", self.workers[w].peer),
                        ));
                    }
                    if let Err(e) = send_to(&mut self.workers[w], setup) {
                        LOG.warn(&format!("phase setup to joined worker {w} failed: {e}"));
                        self.workers[w].alive = false;
                    } else {
                        self.assign_next(w, phase_id, phase_ctx, sched, excluded, assigns);
                    }
                }
                Err(e) => LOG.warn(&format!("failed to register joined worker: {e}")),
            },
        }
    }

    /// Hand the next chunk to an idle worker: a queued chunk it isn't
    /// excluded from, or — once the queue is dry — a speculative duplicate
    /// of the longest-running chunk on some *other* worker.
    fn assign_next(
        &mut self,
        w: usize,
        phase_id: u64,
        phase_ctx: TraceCtx,
        sched: &ChunkScheduler,
        excluded: &mut [Vec<usize>],
        assigns: &mut [u32],
    ) {
        if !self.workers[w].alive || self.workers[w].busy.is_some() || sched.is_finished() {
            return;
        }
        let mut speculative = false;
        let pick = match sched.try_claim(|c| !excluded[c].contains(&w)) {
            Some(c) => Some(c),
            None => {
                let mut best: Option<(usize, Instant)> = None;
                for c in sched.running_chunks() {
                    if excluded[c].contains(&w) {
                        continue;
                    }
                    let runners: Vec<usize> = self
                        .workers
                        .iter()
                        .enumerate()
                        .filter(|(_, wk)| wk.alive && wk.busy == Some((phase_id, c as u32)))
                        .map(|(i, _)| i)
                        .collect();
                    // Duplicate only chunks running on exactly one other
                    // worker (no speculation pile-ups).
                    if runners.len() == 1 && runners[0] != w {
                        let since = self.workers[runners[0]].busy_since;
                        let longer_running = match best {
                            None => true,
                            Some((_, b)) => since < b,
                        };
                        if longer_running {
                            best = Some((c, since));
                        }
                    }
                }
                best.map(|(c, _)| {
                    sched.speculate(c);
                    speculative = true;
                    c
                })
            }
        };
        let Some(c) = pick else { return };
        // Per-assignment span context: the worker adopts it (logs + its
        // local chunk span), and the leader's merged timeline event reuses
        // the same span id, so both sides name one execution identically.
        let actx = if phase_ctx.is_none() {
            TraceCtx::NONE
        } else {
            TraceCtx { trace: phase_ctx.trace, span: next_id() }
        };
        let msg = ToWorker::Assign { phase: phase_id, chunk: c as u32, trace: actx };
        match send_to(&mut self.workers[w], &msg) {
            Ok(()) => {
                self.workers[w].busy = Some((phase_id, c as u32));
                self.workers[w].busy_since = Instant::now();
                self.workers[w].assign_span = actx.span;
                self.workers[w].assign_retry = assigns[c] > 0 && !speculative;
                self.workers[w].assign_speculative = speculative;
                assigns[c] += 1;
            }
            Err(e) => {
                LOG.warn(&format!("assign chunk {c} to worker {w} failed: {e}"));
                self.workers[w].alive = false;
                excluded[c].push(w);
                sched.release(c);
            }
        }
    }

    /// Emit the merged timeline event for one completed chunk execution:
    /// back-dated from the measured elapsed time so it sits on the
    /// leader's trace clock, on the worker's own lane, tagged with the
    /// worker's decode/compute/encode split off the `ChunkDone` frame.
    fn emit_chunk_event(
        &self,
        w: usize,
        phase_ctx: TraceCtx,
        chunk: u32,
        elapsed: Duration,
        sections_us: (u64, u64, u64),
    ) {
        let Some(now_us) = trace::global_now_us() else { return };
        let elapsed_us = elapsed.as_micros() as u64;
        let worker = &self.workers[w];
        let (decode_us, compute_us, encode_us) = sections_us;
        let ev = TraceEvent::complete(
            &format!("chunk {chunk}"),
            "chunk",
            now_us.saturating_sub(elapsed_us),
            elapsed_us,
            WORKER_LANE_BASE + w as u64,
        )
        .arg_str("trace", &format!("{:016x}", phase_ctx.trace))
        .arg_str("span", &format!("{:016x}", worker.assign_span))
        .arg_str("parent", &format!("{:016x}", phase_ctx.span))
        .arg_str("worker", &worker.peer)
        .arg_num("chunk", chunk as f64)
        .arg_num("decode_ms", decode_us as f64 / 1e3)
        .arg_num("compute_ms", compute_us as f64 / 1e3)
        .arg_num("encode_ms", encode_us as f64 / 1e3)
        .arg_bool("retry", worker.assign_retry)
        .arg_bool("speculative", worker.assign_speculative);
        trace::emit_global(&ev);
    }

    /// Fence workers silent past [`STALE_AFTER_MS`]: mark dead, requeue
    /// their in-flight chunks. Runs on event-loop idle ticks.
    fn fence_stale_workers(
        &mut self,
        phase_id: u64,
        sched: &ChunkScheduler,
        excluded: &mut [Vec<usize>],
    ) {
        let cutoff = Duration::from_millis(STALE_AFTER_MS);
        for w in 0..self.workers.len() {
            if self.workers[w].alive && self.workers[w].last_seen.elapsed() > cutoff {
                LOG.warn(&format!(
                    "worker {w} silent for {:.1}s: fencing",
                    self.workers[w].last_seen.elapsed().as_secs_f64()
                ));
                self.workers[w].alive = false;
                if let Some((ph, c)) = self.workers[w].busy.take() {
                    if ph == phase_id {
                        excluded[c as usize].push(w);
                        sched.release(c as usize);
                    }
                }
            }
        }
    }

    /// Tell every still-connected worker to exit (fenced ones included —
    /// they may merely have been slow) and stop accepting joiners. A dead
    /// connection must not stop the others from being told; only failures
    /// to live workers are reported.
    pub fn shutdown(&mut self) -> Result<()> {
        self.stop_accept.store(true, Ordering::Relaxed);
        // Wake the accept thread so it observes the stop flag.
        let _ = TcpStream::connect(&self.listen_addr);
        let mut failure: Option<Error> = None;
        for i in 0..self.workers.len() {
            let was_alive = self.workers[i].alive;
            if let Err(e) = send_to(&mut self.workers[i], &ToWorker::Shutdown) {
                if was_alive && failure.is_none() {
                    failure = Some(Error::Other(format!("shutdown of worker {i} failed: {e}")));
                }
            }
        }
        match failure {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}
