//! [`ClusterExecutor`]: the distributed execution substrate.
//!
//! Implements [`crate::svd::Executor`] by planning each pass's chunk
//! schedule (fine-grained, per [`crate::svd::PassContext::sched`]),
//! streaming the chunk tasks to the connected workers over the
//! leader/worker RPC, and reducing the returned per-chunk partials in
//! chunk order. Only small state crosses the wire — sketch partials,
//! rotation matrices, column means; the tall data never does (the paper's
//! point, made structural by [`super::proto`]).
//!
//! Reduction follows [`PassContext::reduce`]:
//!
//! * **Star** — every partial rides its `ChunkDone` frame and the leader
//!   folds them sequentially (the pre-v6 behavior; leader memory grows
//!   with the chunk count, and a leader memory cap can veto it).
//! * **Tree** — `k'`-scale partials (`AᵀA`, `YᵀY`, column sums) stay as
//!   held leaves on the workers that computed them; the leader relays the
//!   canonical merge rounds ([`crate::svd::reduce::merge_rounds`]) between
//!   holders and only the root crosses to it. The one tall partial — the
//!   final `W = AᵀU₀` — goes through [`Executor::run_wpass`]: band-split
//!   held leaves, per-band merges, a TSQR R-factor fold for the completion,
//!   and worker-side `V` shard writes, so the leader never materializes an
//!   n-sized matrix. Power-iteration `W` partials (consumed leader-side as
//!   the next Ω) still ride the star transport but are folded over the
//!   same merge-round schedule, keeping local/cluster bits identical.
//!
//! The chunk count is anchored to the worker count *at construction*, not
//! the live count: every pass of a run (and the shards it leaves on disk)
//! must share one chunk plan even if workers die or join mid-run.

use super::leader::{DistributedLeader, PhaseSpec};
use super::proto::PhaseKind;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::splitproc;
use crate::svd::executor::publish_sched_stats;
use crate::svd::reduce::{tree_reduce, ReduceMode};
use crate::svd::{Executor, Pass, PassContext, PassOutput, WPassOutput};

/// Map a wire phase back to the pass the worker should run. Inverse of
/// [`wire_parts`]; an all-zero operand means "regenerate Ω from the seed".
pub(crate) fn pass_from_wire(kind: PhaseKind, operand: &Matrix) -> Pass<'_> {
    match kind {
        PhaseKind::ColStats => Pass::ColStats,
        PhaseKind::Ata => Pass::Ata,
        PhaseKind::ProjectGram => Pass::ProjectGram {
            omega: if operand.rows() > 0 { Some(operand) } else { None },
        },
        PhaseKind::UrecoverTmul => Pass::UrecoverTmul { m: operand },
        PhaseKind::Mult => Pass::Mult { m: operand },
        PhaseKind::RotateU => Pass::RotateU { p: operand },
    }
}

/// Map a pass to its wire phase kind and operand (None = empty matrix).
fn wire_parts<'a>(pass: &Pass<'a>) -> (PhaseKind, Option<&'a Matrix>) {
    match *pass {
        Pass::ColStats => (PhaseKind::ColStats, None),
        Pass::Ata => (PhaseKind::Ata, None),
        Pass::ProjectGram { omega } => (PhaseKind::ProjectGram, omega),
        Pass::UrecoverTmul { m } => (PhaseKind::UrecoverTmul, Some(m)),
        Pass::Mult { m } => (PhaseKind::Mult, Some(m)),
        Pass::RotateU { p } => (PhaseKind::RotateU, Some(p)),
    }
}

/// Executor that streams chunk tasks to remote TCP workers through the
/// leader's work queue.
pub struct ClusterExecutor {
    leader: DistributedLeader,
    /// Worker count at construction — anchors the chunk plan for every
    /// pass of the run (see module docs).
    planned_workers: usize,
}

impl ClusterExecutor {
    /// Wrap an already-accepted leader.
    pub fn new(leader: DistributedLeader) -> Self {
        let planned_workers = leader.worker_count().max(1);
        ClusterExecutor { leader, planned_workers }
    }

    /// Bind `listen` and wait for `workers` remote workers to join; more
    /// may join later mid-run.
    pub fn accept(listen: &str, workers: usize) -> Result<Self> {
        Ok(Self::new(DistributedLeader::accept(listen, workers)?))
    }

    /// Number of currently live workers.
    pub fn workers(&self) -> usize {
        self.leader.worker_count()
    }

    /// Access the underlying leader (e.g. for raw phase RPCs or the
    /// reduce-state memory gauge).
    pub fn leader_mut(&mut self) -> &mut DistributedLeader {
        &mut self.leader
    }

    /// High-water mark of leader-resident reduce-state bytes.
    pub fn mem_peak(&self) -> u64 {
        self.leader.mem_peak()
    }

    /// Tell every worker to exit and consume the executor.
    pub fn shutdown(mut self) -> Result<()> {
        self.leader.shutdown()
    }

    /// Plan the run's chunk geometry and assemble the wire-side phase
    /// description shared by every leader entry point.
    fn plan<'a>(
        &self,
        ctx: &'a PassContext,
        kind: PhaseKind,
        operand: &'a Matrix,
        means: &'a Matrix,
    ) -> Result<PhaseSpec<'a>> {
        let chunks = splitproc::plan_chunks_policy(ctx.input, self.planned_workers, &ctx.sched)?;
        let total = chunks.len();
        if total == 0 {
            return Err(Error::Config("input has no rows to chunk".into()));
        }
        Ok(PhaseSpec {
            kind,
            input: ctx.input,
            work_dir: ctx.work_dir,
            block: ctx.block,
            seed: ctx.seed,
            kp: ctx.kp,
            cols: ctx.n,
            shard_format: ctx.shard_format,
            shard_epoch: ctx.shard_epoch,
            operand,
            means,
            chunk_total: total,
            max_retries: ctx.sched.max_retries,
        })
    }
}

fn wire_means(ctx: &PassContext) -> Result<Matrix> {
    if ctx.means.is_empty() {
        Ok(Matrix::zeros(0, 0))
    } else {
        Matrix::from_vec(1, ctx.means.len(), ctx.means.to_vec())
    }
}

/// Phases whose partial is worth keeping distributed: the additive
/// `k'`-scale (or 1×n) accumulations. Shard-only phases (`RotateU`,
/// `Mult`) and the power-iteration `W` (whose sum the leader consumes
/// immediately) stay on the star transport.
fn holds_in_tree(kind: PhaseKind) -> bool {
    matches!(kind, PhaseKind::ProjectGram | PhaseKind::Ata | PhaseKind::ColStats)
}

impl Executor for ClusterExecutor {
    fn name(&self) -> &str {
        "cluster"
    }

    fn run_pass(&mut self, ctx: &PassContext, pass: &Pass) -> Result<PassOutput> {
        let empty = Matrix::zeros(0, 0);
        let (kind, operand) = wire_parts(pass);
        let operand = operand.unwrap_or(&empty);
        let means = wire_means(ctx)?;
        let spec = self.plan(ctx, kind, operand, &means)?;
        let total = spec.chunk_total;
        if ctx.reduce == ReduceMode::Tree && holds_in_tree(kind) {
            let (rows, partial, stats) = self.leader.run_phase_tree(&spec)?;
            publish_sched_stats(pass.name(), &stats);
            return Ok(PassOutput { rows, shards: total, partial: Some(partial), stats });
        }
        let (rows, partials, stats) = self.leader.run_phase(&spec)?;
        // `partials` is in chunk order: the reduction matches the local
        // executor's bit for bit — sequential fold in star mode, the
        // canonical merge-round schedule in tree mode.
        let partial = if partials.is_empty() {
            None
        } else if ctx.reduce == ReduceMode::Tree {
            Some(tree_reduce(partials)?)
        } else {
            Some(splitproc::reduce_partials(partials)?)
        };
        publish_sched_stats(pass.name(), &stats);
        Ok(PassOutput { rows, shards: total, partial, stats })
    }

    fn run_wpass(
        &mut self,
        ctx: &PassContext,
        m: &Matrix,
        k: usize,
        cutoff_rel: f64,
        compute_v: bool,
    ) -> Result<WPassOutput> {
        if ctx.reduce == ReduceMode::Star {
            // Star keeps the pre-v6 shape: full W on the leader, local
            // banded completion.
            let out = self.run_pass(ctx, &Pass::UrecoverTmul { m })?;
            return crate::svd::executor::complete_wpass_from_full(
                out, ctx, k, cutoff_rel, compute_v,
            );
        }
        let means = wire_means(ctx)?;
        let spec = self.plan(ctx, PhaseKind::UrecoverTmul, m, &means)?;
        let total = spec.chunk_total;
        let (rows, sigma_full, p, v_bands, stats) =
            self.leader.run_wphase(&spec, ctx.band_rows as u64, k, cutoff_rel, compute_v)?;
        publish_sched_stats(Pass::UrecoverTmul { m }.name(), &stats);
        Ok(WPassOutput { rows, shards: total, v_bands, sigma_full, p, stats })
    }
}
