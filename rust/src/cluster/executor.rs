//! [`ClusterExecutor`]: the distributed execution substrate.
//!
//! Implements [`crate::svd::Executor`] by shipping each pass description to
//! the connected workers over the leader/worker RPC and reducing the
//! returned partials. Only small state crosses the wire — sketch partials,
//! rotation matrices, column means; the tall data never does (the paper's
//! point, made structural by [`super::proto`]).

use super::leader::DistributedLeader;
use super::proto::PhaseKind;
use crate::error::Result;
use crate::linalg::Matrix;
use crate::splitproc;
use crate::svd::{Executor, Pass, PassContext, PassOutput};

/// Map a wire phase back to the pass the worker should run. Inverse of
/// [`wire_parts`]; an all-zero operand means "regenerate Ω from the seed".
pub(crate) fn pass_from_wire(kind: PhaseKind, operand: &Matrix) -> Pass<'_> {
    match kind {
        PhaseKind::ColStats => Pass::ColStats,
        PhaseKind::Ata => Pass::Ata,
        PhaseKind::ProjectGram => Pass::ProjectGram {
            omega: if operand.rows() > 0 { Some(operand) } else { None },
        },
        PhaseKind::UrecoverTmul => Pass::UrecoverTmul { m: operand },
        PhaseKind::Mult => Pass::Mult { m: operand },
        PhaseKind::RotateU => Pass::RotateU { p: operand },
    }
}

/// Map a pass to its wire phase kind and operand (None = empty matrix).
fn wire_parts<'a>(pass: &Pass<'a>) -> (PhaseKind, Option<&'a Matrix>) {
    match *pass {
        Pass::ColStats => (PhaseKind::ColStats, None),
        Pass::Ata => (PhaseKind::Ata, None),
        Pass::ProjectGram { omega } => (PhaseKind::ProjectGram, omega),
        Pass::UrecoverTmul { m } => (PhaseKind::UrecoverTmul, Some(m)),
        Pass::Mult { m } => (PhaseKind::Mult, Some(m)),
        Pass::RotateU { p } => (PhaseKind::RotateU, Some(p)),
    }
}

/// Executor that fans passes out to remote TCP workers. Worker `i` always
/// processes chunk `i` of the deterministic chunk plan both sides compute
/// from the shared input file.
pub struct ClusterExecutor {
    leader: DistributedLeader,
}

impl ClusterExecutor {
    /// Wrap an already-accepted leader.
    pub fn new(leader: DistributedLeader) -> Self {
        ClusterExecutor { leader }
    }

    /// Bind `listen` and wait for `workers` remote workers to join.
    pub fn accept(listen: &str, workers: usize) -> Result<Self> {
        Ok(Self::new(DistributedLeader::accept(listen, workers)?))
    }

    /// Number of connected workers (= chunk/shard count of every pass).
    pub fn workers(&self) -> usize {
        self.leader.worker_count()
    }

    /// Access the underlying leader (e.g. for raw phase RPCs).
    pub fn leader_mut(&mut self) -> &mut DistributedLeader {
        &mut self.leader
    }

    /// Tell every worker to exit and consume the executor.
    pub fn shutdown(mut self) -> Result<()> {
        self.leader.shutdown()
    }
}

impl Executor for ClusterExecutor {
    fn name(&self) -> &str {
        "cluster"
    }

    fn run_pass(&mut self, ctx: &PassContext, pass: &Pass) -> Result<PassOutput> {
        let empty = Matrix::zeros(0, 0);
        let (kind, operand) = wire_parts(pass);
        let operand = operand.unwrap_or(&empty);
        let means = if ctx.means.is_empty() {
            Matrix::zeros(0, 0)
        } else {
            Matrix::from_vec(1, ctx.means.len(), ctx.means.to_vec())?
        };
        let (rows, partials) = self.leader.run_phase(
            kind,
            ctx.input,
            ctx.work_dir,
            ctx.block,
            ctx.seed,
            ctx.kp,
            ctx.n,
            ctx.shard_format,
            operand,
            &means,
        )?;
        let partial = if partials.is_empty() {
            None
        } else {
            Some(splitproc::reduce_partials(partials)?)
        };
        Ok(PassOutput { rows, shards: self.leader.worker_count(), partial })
    }
}
