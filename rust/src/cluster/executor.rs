//! [`ClusterExecutor`]: the distributed execution substrate.
//!
//! Implements [`crate::svd::Executor`] by planning each pass's chunk
//! schedule (fine-grained, per [`crate::svd::PassContext::sched`]),
//! streaming the chunk tasks to the connected workers over the
//! leader/worker RPC, and reducing the returned per-chunk partials in
//! chunk order. Only small state crosses the wire — sketch partials,
//! rotation matrices, column means; the tall data never does (the paper's
//! point, made structural by [`super::proto`]).
//!
//! The chunk count is anchored to the worker count *at construction*, not
//! the live count: every pass of a run (and the shards it leaves on disk)
//! must share one chunk plan even if workers die or join mid-run.

use super::leader::DistributedLeader;
use super::proto::PhaseKind;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::splitproc;
use crate::svd::executor::publish_sched_stats;
use crate::svd::{Executor, Pass, PassContext, PassOutput};

/// Map a wire phase back to the pass the worker should run. Inverse of
/// [`wire_parts`]; an all-zero operand means "regenerate Ω from the seed".
pub(crate) fn pass_from_wire(kind: PhaseKind, operand: &Matrix) -> Pass<'_> {
    match kind {
        PhaseKind::ColStats => Pass::ColStats,
        PhaseKind::Ata => Pass::Ata,
        PhaseKind::ProjectGram => Pass::ProjectGram {
            omega: if operand.rows() > 0 { Some(operand) } else { None },
        },
        PhaseKind::UrecoverTmul => Pass::UrecoverTmul { m: operand },
        PhaseKind::Mult => Pass::Mult { m: operand },
        PhaseKind::RotateU => Pass::RotateU { p: operand },
    }
}

/// Map a pass to its wire phase kind and operand (None = empty matrix).
fn wire_parts<'a>(pass: &Pass<'a>) -> (PhaseKind, Option<&'a Matrix>) {
    match *pass {
        Pass::ColStats => (PhaseKind::ColStats, None),
        Pass::Ata => (PhaseKind::Ata, None),
        Pass::ProjectGram { omega } => (PhaseKind::ProjectGram, omega),
        Pass::UrecoverTmul { m } => (PhaseKind::UrecoverTmul, Some(m)),
        Pass::Mult { m } => (PhaseKind::Mult, Some(m)),
        Pass::RotateU { p } => (PhaseKind::RotateU, Some(p)),
    }
}

/// Executor that streams chunk tasks to remote TCP workers through the
/// leader's work queue.
pub struct ClusterExecutor {
    leader: DistributedLeader,
    /// Worker count at construction — anchors the chunk plan for every
    /// pass of the run (see module docs).
    planned_workers: usize,
}

impl ClusterExecutor {
    /// Wrap an already-accepted leader.
    pub fn new(leader: DistributedLeader) -> Self {
        let planned_workers = leader.worker_count().max(1);
        ClusterExecutor { leader, planned_workers }
    }

    /// Bind `listen` and wait for `workers` remote workers to join; more
    /// may join later mid-run.
    pub fn accept(listen: &str, workers: usize) -> Result<Self> {
        Ok(Self::new(DistributedLeader::accept(listen, workers)?))
    }

    /// Number of currently live workers.
    pub fn workers(&self) -> usize {
        self.leader.worker_count()
    }

    /// Access the underlying leader (e.g. for raw phase RPCs).
    pub fn leader_mut(&mut self) -> &mut DistributedLeader {
        &mut self.leader
    }

    /// Tell every worker to exit and consume the executor.
    pub fn shutdown(mut self) -> Result<()> {
        self.leader.shutdown()
    }
}

impl Executor for ClusterExecutor {
    fn name(&self) -> &str {
        "cluster"
    }

    fn run_pass(&mut self, ctx: &PassContext, pass: &Pass) -> Result<PassOutput> {
        // Plan leader-side (the plan is a fixed point of its own count, so
        // workers reproduce identical geometry from `(index, total)`).
        let chunks = splitproc::plan_chunks_policy(ctx.input, self.planned_workers, &ctx.sched)?;
        let total = chunks.len();
        if total == 0 {
            return Err(Error::Config("input has no rows to chunk".into()));
        }
        let empty = Matrix::zeros(0, 0);
        let (kind, operand) = wire_parts(pass);
        let operand = operand.unwrap_or(&empty);
        let means = if ctx.means.is_empty() {
            Matrix::zeros(0, 0)
        } else {
            Matrix::from_vec(1, ctx.means.len(), ctx.means.to_vec())?
        };
        let (rows, partials, stats) = self.leader.run_phase(
            kind,
            ctx.input,
            ctx.work_dir,
            ctx.block,
            ctx.seed,
            ctx.kp,
            ctx.n,
            ctx.shard_format,
            ctx.shard_epoch,
            operand,
            &means,
            total,
            ctx.sched.max_retries,
        )?;
        // `partials` is in chunk order: the reduction matches the local
        // executor's bit for bit.
        let partial = if partials.is_empty() {
            None
        } else {
            Some(splitproc::reduce_partials(partials)?)
        };
        publish_sched_stats(pass.name(), &stats);
        Ok(PassOutput { rows, shards: total, partial, stats })
    }
}
