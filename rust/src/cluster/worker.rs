//! The worker process: connects to the leader, pulls chunk assignments
//! off the leader's queue, executes them over the shared input file, and
//! acks each chunk individually.
//!
//! A pass arrives as one `Phase` setup frame (operand, means, geometry)
//! followed by any number of `Assign { chunk }` frames — the worker is a
//! loop, not a one-shot: it keeps taking chunks as long as the leader has
//! queued work, which is what lets a fast worker absorb a slow one's
//! backlog and a late joiner pick up mid-pass. Each assignment is decoded
//! into the same [`crate::svd::Pass`]/[`PassContext`] pair the in-process
//! [`crate::svd::LocalExecutor`] uses, then handed to
//! [`crate::svd::execute_pass_chunk`] — the pass structure is defined once
//! and this module only does transport.
//!
//! A background thread emits a [`ToLeader::Heartbeat`] every
//! [`HEARTBEAT_MS`] even while a chunk is executing, so the leader can
//! tell "slow" from "gone" and requeue a dead worker's chunks.

use super::proto::{
    FetchWhat, PhaseKind, ToLeader, ToWorker, CAP_CODEC, CAP_HOLD, HOLD_NONE, VERSION,
};
use crate::backend::BackendRef;
use crate::cluster::pass_from_wire;
use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::writer::ShardSet;
use crate::io::InputSpec;
use crate::linalg::{matmul, Matrix};
use crate::obs::trace::{self, Span, TraceCtx};
use crate::rng::VirtualMatrix;
use crate::splitproc::{self, ChunkMeta, SchedPolicy};
use crate::svd::reduce::{self, ReduceMode};
use crate::svd::{execute_pass_chunk, Pass, PassContext};
use crate::util::Logger;
use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

static LOG: Logger = Logger::new("cluster.worker");

/// Heartbeat period (leaders treat silence ~20x longer than this as death).
pub const HEARTBEAT_MS: u64 = 500;

/// The decoded, worker-side state of one `Phase` setup frame, plus the
/// per-phase caches: the chunk plan (one planning pass over the shared
/// file instead of one per assignment) and the seed-derived Ω (one
/// materialization per ProjectGram phase instead of one per chunk —
/// matching what `LocalExecutor::run_pass` hoists).
pub struct PhaseConfig {
    pub id: u64,
    pub kind: PhaseKind,
    pub input: InputSpec,
    pub work_dir: String,
    pub chunk_total: usize,
    pub block: usize,
    pub seed: u64,
    pub kp: usize,
    pub cols: usize,
    pub shard_format: InputFormat,
    pub shard_epoch: u32,
    pub operand: Matrix,
    pub means: Vec<f64>,
    /// Leader's phase span context (NONE when the run isn't traced).
    pub trace: TraceCtx,
    /// Tree-reduce hold mode: keep chunk partials as in-memory reduce
    /// leaves instead of shipping them with `ChunkDone`.
    pub hold: bool,
    /// Band height for held leaves (0 = one band per partial).
    pub band_rows: usize,
    plan: OnceLock<Vec<ChunkMeta>>,
    omega: OnceLock<Matrix>,
}

impl PhaseConfig {
    /// Decode a [`ToWorker::Phase`] frame.
    pub fn from_msg(msg: &ToWorker) -> Result<PhaseConfig> {
        let ToWorker::Phase {
            id,
            kind,
            input_path,
            input_format,
            work_dir,
            chunk_total,
            block,
            seed,
            kp,
            cols,
            shard_format,
            shard_epoch,
            operand,
            means,
            trace,
            hold,
            band_rows,
        } = msg
        else {
            return Err(Error::Other("PhaseConfig::from_msg on non-phase message".into()));
        };
        Ok(PhaseConfig {
            id: *id,
            kind: *kind,
            input: InputSpec { path: input_path.clone(), format: *input_format },
            work_dir: work_dir.clone(),
            chunk_total: *chunk_total as usize,
            block: *block as usize,
            seed: *seed,
            kp: *kp as usize,
            cols: *cols as usize,
            shard_format: *shard_format,
            shard_epoch: *shard_epoch,
            operand: operand.clone(),
            means: if means.rows() > 0 { means.row(0).to_vec() } else { Vec::new() },
            trace: *trace,
            hold: *hold,
            band_rows: *band_rows as usize,
            plan: OnceLock::new(),
            omega: OnceLock::new(),
        })
    }

    /// Chunk `index` of this phase's plan, computing and caching the plan
    /// on first use (lazy so a bad input surfaces as a per-chunk failure
    /// the leader can handle, not a dead connection).
    fn chunk(&self, index: usize) -> Result<ChunkMeta> {
        let chunks = match self.plan.get() {
            Some(chunks) => chunks,
            None => {
                // Both sides compute the same deterministic chunk plan
                // from the shared file — only (index, total) crosses the
                // wire. The leader's plan is a fixed point of
                // `plan_chunks`, so replanning from the count alone
                // reproduces its exact boundaries.
                let computed = splitproc::plan_chunks(&self.input, self.chunk_total)?;
                self.plan.get_or_init(|| computed)
            }
        };
        chunks.get(index).copied().ok_or_else(|| {
            Error::Config(format!("chunk {index} of {} does not exist", self.chunk_total))
        })
    }
}

/// Execute one chunk assignment of the current phase. Returns
/// `(rows_streamed, partial)` — the partial is 0x0 for shard-only passes.
pub fn execute_assignment(
    backend: &BackendRef,
    cfg: &PhaseConfig,
    chunk_index: usize,
) -> Result<(u64, Matrix)> {
    std::fs::create_dir_all(&cfg.work_dir)?;
    let chunk = cfg.chunk(chunk_index)?;
    let ctx = PassContext {
        input: &cfg.input,
        backend: backend.clone(),
        work_dir: cfg.work_dir.as_str(),
        shard_format: cfg.shard_format,
        block: cfg.block,
        seed: cfg.seed,
        n: cfg.cols,
        kp: cfg.kp,
        means: Arc::new(cfg.means.clone()),
        // Scheduling and reduction happen leader-side; the worker only
        // ever sees one chunk at a time.
        sched: SchedPolicy::default(),
        shard_epoch: cfg.shard_epoch,
        reduce: ReduceMode::Star,
        band_rows: 0,
    };
    // Materialize a seed-derived Ω once per phase, not once per chunk
    // (every chunk would regenerate identical bits).
    let pass = if cfg.kind == PhaseKind::ProjectGram && cfg.operand.rows() == 0 {
        let omega = cfg
            .omega
            .get_or_init(|| VirtualMatrix::projection(cfg.seed, cfg.cols, cfg.kp).materialize());
        Pass::ProjectGram { omega: Some(omega) }
    } else {
        pass_from_wire(cfg.kind, &cfg.operand)
    };
    let (rows, partial) = execute_pass_chunk(&ctx, &pass, &chunk)?;
    Ok((rows, partial.unwrap_or_else(|| Matrix::zeros(0, 0))))
}

fn send(writer: &Mutex<TcpStream>, msg: &ToLeader) -> Result<()> {
    let guard = writer.lock().unwrap();
    let mut stream: &TcpStream = &guard;
    msg.write(&mut stream)
}

/// Serve one leader connection until `Shutdown`. Used by the `worker`
/// subcommand and (in-process) by the cluster tests.
pub fn serve(stream: TcpStream, backend: BackendRef) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = stream.try_clone()?;
    let writer = Arc::new(Mutex::new(stream));
    send(&writer, &ToLeader::Hello { version: VERSION, caps: CAP_HOLD | CAP_CODEC })?;

    // Liveness: heartbeat from a side thread so a long chunk execution
    // doesn't read as death. The thread dies with the connection (its
    // write fails) or at shutdown (the stop flag).
    let stop = Arc::new(AtomicBool::new(false));
    let hb_stop = stop.clone();
    let hb_writer = writer.clone();
    // The handle is deliberately never joined — shutdown must not block on
    // the heartbeat interval; the detached thread exits on its next tick
    // (stop flag) or when its write fails on the closed socket.
    let _heartbeat = std::thread::spawn(move || loop {
        std::thread::sleep(std::time::Duration::from_millis(HEARTBEAT_MS));
        if hb_stop.load(Ordering::Relaxed) {
            break;
        }
        if send(&hb_writer, &ToLeader::Heartbeat).is_err() {
            break;
        }
    });

    let result = serve_loop(&mut reader, &writer, &backend);
    stop.store(true, Ordering::Relaxed);
    result
}

fn serve_loop(
    reader: &mut TcpStream,
    writer: &Mutex<TcpStream>,
    backend: &BackendRef,
) -> Result<()> {
    let mut phase: Option<PhaseConfig> = None;
    // Held reduce leaves of the current phase, keyed `(span lo, band)` —
    // span lo is the chunk index the merged span is anchored at.
    let mut held: HashMap<(u32, u32), Matrix> = HashMap::new();
    loop {
        let msg = ToWorker::read(reader)?;
        match msg {
            ToWorker::Shutdown => {
                LOG.info("shutdown received");
                return Ok(());
            }
            msg @ ToWorker::Phase { .. } => {
                let cfg = PhaseConfig::from_msg(&msg)?;
                if phase.as_ref().map(|p| p.id) != Some(cfg.id) {
                    // New phase (or restarted attempt): drop the previous
                    // phase's leaves. A same-id replay — the leader
                    // unfencing us — must keep them.
                    held.clear();
                }
                LOG.info(&format!(
                    "phase {} setup: {:?}, {} chunks{}",
                    cfg.id,
                    cfg.kind,
                    cfg.chunk_total,
                    if cfg.hold { " (hold)" } else { "" }
                ));
                phase = Some(cfg);
            }
            ToWorker::Assign { phase: pid, chunk, trace: actx } => {
                let reply = match phase.as_ref() {
                    Some(cfg) if cfg.id == pid => {
                        // Adopt the leader's assignment context so worker
                        // logs correlate, and measure the chunk's
                        // decode/compute/encode split for the leader's
                        // merged timeline.
                        let _span = Span::with_parent(&format!("chunk {chunk}"), "chunk", actx);
                        LOG.debug(&format!("phase {pid} chunk {chunk}/{}", cfg.chunk_total));
                        trace::sections_begin();
                        let outcome = execute_assignment(backend, cfg, chunk as usize);
                        let sec = trace::sections_take().unwrap_or_default();
                        match outcome {
                            Ok((rows, partial)) => {
                                let wire = if cfg.hold && partial.rows() > 0 {
                                    // Keep the partial here as band-split
                                    // reduce leaves; the leader gets rows
                                    // + completion only.
                                    let bands =
                                        reduce::band_ranges(partial.rows(), cfg.band_rows);
                                    for (b, (lo, hi)) in bands.into_iter().enumerate() {
                                        held.insert(
                                            (chunk, b as u32),
                                            partial.slice_rows(lo, hi),
                                        );
                                    }
                                    Matrix::zeros(0, 0)
                                } else {
                                    partial
                                };
                                ToLeader::ChunkDone {
                                    phase: pid,
                                    chunk,
                                    rows,
                                    decode_us: sec.decode_us,
                                    compute_us: sec.compute_us,
                                    encode_us: sec.encode_us,
                                    partial: wire,
                                }
                            }
                            Err(e) => {
                                // Report and keep serving — the leader
                                // decides (retry elsewhere or fail).
                                LOG.error(&format!("chunk {chunk} failed: {e}"));
                                ToLeader::ChunkFailed {
                                    phase: pid,
                                    chunk,
                                    message: e.to_string(),
                                }
                            }
                        }
                    }
                    _ => ToLeader::ChunkFailed {
                        phase: pid,
                        chunk,
                        message: format!("assignment for unknown phase {pid}"),
                    },
                };
                send(writer, &reply)?;
            }
            ToWorker::RMerge { phase: pid, dst_lo, band, left_held, right_held, src } => {
                let outcome = reduce_merge(&mut held, dst_lo, band, left_held, right_held, src);
                let reply = match outcome {
                    Ok(()) => ToLeader::ReduceDone { phase: pid, lo: dst_lo, band },
                    Err(e) => {
                        LOG.error(&format!("merge into ({dst_lo}, {band}) failed: {e}"));
                        ToLeader::ReduceFailed {
                            phase: pid,
                            lo: dst_lo,
                            band,
                            message: e.to_string(),
                        }
                    }
                };
                send(writer, &reply)?;
            }
            ToWorker::RFetch { phase: pid, lo, band, what } => {
                let outcome = match what {
                    FetchWhat::Partial => held
                        .remove(&(lo, band))
                        .ok_or_else(|| missing_leaf(lo, band)),
                    FetchWhat::RFactor => held
                        .get(&(lo, band))
                        .ok_or_else(|| missing_leaf(lo, band))
                        .and_then(reduce::band_r_factor),
                };
                let reply = match outcome {
                    Ok(matrix) => ToLeader::ReducePart { phase: pid, lo, band, matrix },
                    Err(e) => {
                        LOG.error(&format!("fetch of ({lo}, {band}) failed: {e}"));
                        ToLeader::ReduceFailed { phase: pid, lo, band, message: e.to_string() }
                    }
                };
                send(writer, &reply)?;
            }
            ToWorker::RWriteV { phase: pid, lo, band, shard, mv } => {
                let outcome = write_v_shard(&phase, &held, pid, lo, band, shard, &mv);
                let reply = match outcome {
                    Ok(()) => ToLeader::ReduceDone { phase: pid, lo, band },
                    Err(e) => {
                        LOG.error(&format!("V shard {shard} write failed: {e}"));
                        ToLeader::ReduceFailed { phase: pid, lo, band, message: e.to_string() }
                    }
                };
                send(writer, &reply)?;
            }
        }
    }
}

fn missing_leaf(lo: u32, band: u32) -> Error {
    Error::Other(format!("no held reduce leaf ({lo}, {band})"))
}

/// One pairwise merge step: combine exactly the two operands the leader
/// named — a held leaf per non-[`HOLD_NONE`] name, plus the wire matrix
/// when present — and hold the sum at `(dst_lo, band)`. Operand names are
/// explicit so a stale leaf left by a lost speculative execution can
/// never leak into a sum.
fn reduce_merge(
    held: &mut HashMap<(u32, u32), Matrix>,
    dst_lo: u32,
    band: u32,
    left_held: u32,
    right_held: u32,
    src: Matrix,
) -> Result<()> {
    let mut ops: Vec<Matrix> = Vec::with_capacity(2);
    for name in [left_held, right_held] {
        if name != HOLD_NONE {
            ops.push(held.remove(&(name, band)).ok_or_else(|| missing_leaf(name, band))?);
        }
    }
    if src.rows() > 0 {
        ops.push(src);
    }
    if ops.len() != 2 {
        return Err(Error::Other(format!(
            "merge into ({dst_lo}, {band}) resolved {} operands, need exactly 2",
            ops.len()
        )));
    }
    // The pairwise leaf of the tree — element-wise f64 addition, which is
    // bitwise commutative, so operand order is free.
    let merged = splitproc::reduce_partials(ops)?;
    held.insert((dst_lo, band), merged);
    Ok(())
}

/// Finish the W reduce for one band: `V_band = W_band · M_v`, written as
/// a staged row shard of the `V` [`ShardSet`] — the dense factor never
/// travels to the leader.
fn write_v_shard(
    phase: &Option<PhaseConfig>,
    held: &HashMap<(u32, u32), Matrix>,
    pid: u64,
    lo: u32,
    band: u32,
    shard: u32,
    mv: &Matrix,
) -> Result<()> {
    let cfg = phase
        .as_ref()
        .filter(|p| p.id == pid)
        .ok_or_else(|| Error::Other(format!("v-write for unknown phase {pid}")))?;
    let wband = held.get(&(lo, band)).ok_or_else(|| missing_leaf(lo, band))?;
    let v = matmul(wband, mv)?;
    let set = ShardSet::new(&cfg.work_dir, "V", cfg.shard_format)?;
    let mut w = set.open_writer(shard as usize, v.cols())?;
    for r in 0..v.rows() {
        w.write_row(v.row(r))?;
    }
    w.finish()?;
    Ok(())
}

/// `tallfat worker --leader host:port`: connect and serve until shutdown.
pub fn run_worker(leader_addr: &str, backend: BackendRef) -> Result<()> {
    LOG.info(&format!("connecting to leader at {leader_addr}"));
    let stream = TcpStream::connect(leader_addr)?;
    serve(stream, backend)
}
