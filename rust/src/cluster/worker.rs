//! The worker process: connects to the leader, executes phase assignments
//! over its chunk of the shared input file, ships partials back.

use super::proto::{PhaseKind, ToLeader, ToWorker, VERSION};
use crate::backend::BackendRef;
use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::writer::ShardSet;
use crate::io::InputSpec;
use crate::jobs::{AtaBlockJob, Pass2Job, ProjectGramJob};
use crate::linalg::{matmul, Matrix};
use crate::rng::VirtualMatrix;
use crate::splitproc::{self, Blocked};
use crate::util::Logger;
use std::net::TcpStream;

static LOG: Logger = Logger::new("cluster.worker");

/// Execute one phase assignment. Returns `(rows_streamed, partial)`.
pub fn execute_phase(backend: &BackendRef, msg: &ToWorker) -> Result<(u64, Matrix)> {
    let ToWorker::Phase {
        kind,
        input_path,
        work_dir,
        chunk_index,
        chunk_total,
        block,
        seed,
        kp,
        operand,
    } = msg
    else {
        return Err(Error::Other("execute_phase on non-phase message".into()));
    };
    let input = InputSpec::auto(input_path.clone());
    let (_, n) = input.dims()?;
    let block = *block as usize;
    let kp = *kp as usize;
    let ci = *chunk_index as usize;
    let total = *chunk_total as usize;
    std::fs::create_dir_all(work_dir)?;

    // Both sides compute the same deterministic chunk plan from the shared
    // file — only (index, total) crosses the wire.
    let chunks = splitproc::plan_chunks(&input, total)?;
    let chunk = *chunks
        .get(ci)
        .ok_or_else(|| Error::Config(format!("chunk {ci} of {total} does not exist")))?;

    match kind {
        PhaseKind::ProjectGram => {
            // Virtual-B across the cluster: Ω regenerated from the seed
            // unless the leader sent a power-iteration override.
            let omega = if operand.rows() > 0 {
                operand.clone()
            } else {
                VirtualMatrix::projection(*seed, n, kp).materialize()
            };
            let y_shards = ShardSet::new(work_dir, "Y", InputFormat::Bin)?;
            let job = ProjectGramJob::new(backend.clone(), omega, &y_shards, ci)?;
            let mut blocked = Blocked::new(job, block, n);
            let rows = splitproc::run_chunk(&input, &chunk, &mut blocked)?;
            Ok((rows, blocked.into_inner().into_gram_partial()))
        }
        PhaseKind::UrecoverTmul => {
            let y_shards = ShardSet::new(work_dir, "Y", InputFormat::Bin)?;
            let u0_shards = ShardSet::new(work_dir, "U0", InputFormat::Bin)?;
            let job = Pass2Job::new(
                backend.clone(),
                operand.clone(),
                &y_shards,
                &u0_shards,
                ci,
                n,
            )?;
            let mut blocked = Blocked::new(job, block, n);
            let rows = splitproc::run_chunk(&input, &chunk, &mut blocked)?;
            Ok((rows, blocked.into_inner().into_w_partial()))
        }
        PhaseKind::RotateU => {
            let u0_shards = ShardSet::new(work_dir, "U0", InputFormat::Bin)?;
            let u_shards = ShardSet::new(work_dir, "U", InputFormat::Bin)?;
            let rows = rotate_one_shard(&u0_shards, &u_shards, ci, operand, block)?;
            Ok((rows, Matrix::zeros(0, 0)))
        }
        PhaseKind::Ata => {
            let job = AtaBlockJob::new(backend.clone(), n);
            let mut blocked = Blocked::new(job, block, n);
            let rows = splitproc::run_chunk(&input, &chunk, &mut blocked)?;
            Ok((rows, blocked.into_inner().into_partial()))
        }
    }
}

/// `U = U0 P` over one shard (pass 3, worker side).
fn rotate_one_shard(
    src: &ShardSet,
    dst: &ShardSet,
    index: usize,
    p: &Matrix,
    block: usize,
) -> Result<u64> {
    let mut reader = src.open_reader(index)?;
    let mut writer = dst.open_writer(index, p.cols())?;
    let mut row = Vec::new();
    let mut buf: Vec<Vec<f64>> = Vec::with_capacity(block);
    let mut count = 0u64;
    loop {
        buf.clear();
        while buf.len() < block {
            if !reader.next_row(&mut row)? {
                break;
            }
            buf.push(row.clone());
        }
        if buf.is_empty() {
            break;
        }
        let u0 = Matrix::from_rows(&buf)?;
        let u = matmul(&u0, p)?;
        for r in 0..u.rows() {
            writer.write_row(u.row(r))?;
        }
        count += u.rows() as u64;
        if buf.len() < block {
            break;
        }
    }
    writer.finish()?;
    Ok(count)
}

/// Serve one leader connection until `Shutdown`. Used by the `worker`
/// subcommand and (in-process) by the cluster tests.
pub fn serve(stream: TcpStream, backend: BackendRef) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = stream.try_clone()?;
    let mut writer = stream;
    ToLeader::Hello { version: VERSION }.write(&mut writer)?;
    loop {
        let msg = ToWorker::read(&mut reader)?;
        match &msg {
            ToWorker::Shutdown => {
                LOG.info("shutdown received");
                return Ok(());
            }
            ToWorker::Phase { kind, chunk_index, chunk_total, .. } => {
                LOG.info(&format!("phase {kind:?} chunk {chunk_index}/{chunk_total}"));
                match execute_phase(&backend, &msg) {
                    Ok((rows, partial)) => {
                        ToLeader::Partial { rows, partial }.write(&mut writer)?;
                    }
                    Err(e) => {
                        // Report and keep serving — the leader decides.
                        LOG.error(&format!("phase failed: {e}"));
                        ToLeader::Failed { message: e.to_string() }.write(&mut writer)?;
                    }
                }
            }
        }
    }
}

/// `tallfat worker --leader host:port`: connect and serve until shutdown.
pub fn run_worker(leader_addr: &str, backend: BackendRef) -> Result<()> {
    LOG.info(&format!("connecting to leader at {leader_addr}"));
    let stream = TcpStream::connect(leader_addr)?;
    serve(stream, backend)
}
