//! The worker process: connects to the leader, executes phase assignments
//! over its chunk of the shared input file, ships partials back.
//!
//! A phase assignment is decoded into the same [`crate::svd::Pass`]/[`PassContext`]
//! pair the in-process [`crate::svd::LocalExecutor`] uses, then handed to
//! [`crate::svd::execute_pass_chunk`] — the pass structure is defined once
//! and this module only does transport.

use super::proto::{ToLeader, ToWorker, VERSION};
use crate::backend::BackendRef;
use crate::cluster::pass_from_wire;
use crate::error::{Error, Result};
use crate::io::InputSpec;
use crate::linalg::Matrix;
use crate::splitproc;
use crate::svd::{execute_pass_chunk, PassContext};
use crate::util::Logger;
use std::net::TcpStream;
use std::sync::Arc;

static LOG: Logger = Logger::new("cluster.worker");

/// Execute one phase assignment. Returns `(rows_streamed, partial)`.
pub fn execute_phase(backend: &BackendRef, msg: &ToWorker) -> Result<(u64, Matrix)> {
    let ToWorker::Phase {
        kind,
        input_path,
        input_format,
        work_dir,
        chunk_index,
        chunk_total,
        block,
        seed,
        kp,
        cols,
        shard_format,
        operand,
        means,
    } = msg
    else {
        return Err(Error::Other("execute_phase on non-phase message".into()));
    };
    let input = InputSpec { path: input_path.clone(), format: *input_format };
    let n = *cols as usize;
    let ci = *chunk_index as usize;
    let total = *chunk_total as usize;
    std::fs::create_dir_all(work_dir)?;

    // Both sides compute the same deterministic chunk plan from the shared
    // file — only (index, total) crosses the wire.
    let chunks = splitproc::plan_chunks(&input, total)?;
    let chunk = *chunks
        .get(ci)
        .ok_or_else(|| Error::Config(format!("chunk {ci} of {total} does not exist")))?;

    let means_vec: Vec<f64> = if means.rows() > 0 { means.row(0).to_vec() } else { Vec::new() };
    let ctx = PassContext {
        input: &input,
        backend: backend.clone(),
        work_dir: work_dir.as_str(),
        shard_format: *shard_format,
        block: *block as usize,
        seed: *seed,
        n,
        kp: *kp as usize,
        means: Arc::new(means_vec),
    };
    let pass = pass_from_wire(*kind, operand);
    let (rows, partial) = execute_pass_chunk(&ctx, &pass, &chunk)?;
    Ok((rows, partial.unwrap_or_else(|| Matrix::zeros(0, 0))))
}

/// Serve one leader connection until `Shutdown`. Used by the `worker`
/// subcommand and (in-process) by the cluster tests.
pub fn serve(stream: TcpStream, backend: BackendRef) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = stream.try_clone()?;
    let mut writer = stream;
    ToLeader::Hello { version: VERSION }.write(&mut writer)?;
    loop {
        let msg = ToWorker::read(&mut reader)?;
        match &msg {
            ToWorker::Shutdown => {
                LOG.info("shutdown received");
                return Ok(());
            }
            ToWorker::Phase { kind, chunk_index, chunk_total, .. } => {
                LOG.info(&format!("phase {kind:?} chunk {chunk_index}/{chunk_total}"));
                match execute_phase(&backend, &msg) {
                    Ok((rows, partial)) => {
                        ToLeader::Partial { rows, partial }.write(&mut writer)?;
                    }
                    Err(e) => {
                        // Report and keep serving — the leader decides.
                        LOG.error(&format!("phase failed: {e}"));
                        ToLeader::Failed { message: e.to_string() }.write(&mut writer)?;
                    }
                }
            }
        }
    }
}

/// `tallfat worker --leader host:port`: connect and serve until shutdown.
pub fn run_worker(leader_addr: &str, backend: BackendRef) -> Result<()> {
    LOG.info(&format!("connecting to leader at {leader_addr}"));
    let stream = TcpStream::connect(leader_addr)?;
    serve(stream, backend)
}
