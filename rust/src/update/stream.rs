//! Fold a finished one-pass streaming factorization into a saved model as
//! its next generation.
//!
//! The multi-pass update ([`crate::update::builder`]) re-reads the new row
//! batch three times — impossible when the rows arrived over a pipe and are
//! gone. The streaming route instead factors the batch *as it passes by*
//! ([`crate::stream::StreamSvd`]) and hands this module the finished
//! factors: the merge is then two already-orthonormal blocks glued with
//! [`merge_factored`]'s `(k₀+k₁+2)²` eigensolve, the old generation's `U`
//! shards rotate by `P_old`, the stream's `U` shards rotate by `P_new`, and
//! the generation commits with the same manifest/`CURRENT` protocol the
//! multi-pass update uses — a serving daemon hot-swaps to it with zero
//! downtime.

use crate::backend::BackendRef;
use crate::config::InputFormat;
use crate::coordinator::server::MetricsRegistry;
use crate::error::{Error, Result};
use crate::io::writer::{ShardReader, ShardSet, ShardWriter};
use crate::linalg::{matmul, Matrix};
use crate::metrics::PhaseReport;
use crate::io::manifest::KvManifest;
use crate::serve::store::{
    begin_generation, embedding_norm, gc_generations, generation_dir_name, list_generations,
    model_manifest, next_generation, publish_generation, ModelStore,
};
use crate::svd::SvdResult;
use crate::update::merge::{merge_factored, FactoredBlock};
use crate::update::UpdateResult;
use crate::util::Logger;
use std::path::Path;
use std::time::Instant;

static LOG: Logger = Logger::new("update");

/// Options for [`publish_stream_result`].
pub struct StreamPublish {
    /// Rank of the next generation (None = keep the model's k; capped at
    /// the merged basis width).
    pub rank: Option<usize>,
    /// Generations surviving GC after the publish (min 1).
    pub keep_generations: usize,
    /// Ω seed recorded in the manifest (the stream's seed).
    pub seed: Option<u64>,
    /// Daemon job id recorded in the generation manifest. When set, the
    /// publish is idempotent per id: if some generation already carries it
    /// (a reaped-but-alive predecessor committed before this retry), that
    /// generation is returned instead of appending the same rows twice.
    pub job_id: Option<u64>,
    /// Called at shard-rotation boundaries — a long publish tail can
    /// otherwise outlive a supervisor's heartbeat horizon.
    pub progress: Option<std::sync::Arc<dyn Fn() + Send + Sync>>,
}

impl Default for StreamPublish {
    fn default() -> Self {
        StreamPublish {
            rank: None,
            keep_generations: 2,
            seed: None,
            job_id: None,
            progress: None,
        }
    }
}

/// Merge a stream run's [`SvdResult`] into the model at `root` and publish
/// the next generation. The stream must have been run with
/// `.cols(model.n)` (so the column dictionaries align) and `.center`
/// matching the model's centeredness.
pub fn publish_stream_result(
    root: impl AsRef<Path>,
    result: &SvdResult,
    backend: &BackendRef,
    opts: &StreamPublish,
) -> Result<UpdateResult> {
    let root = root.as_ref();
    if let Some(job_id) = opts.job_id {
        if let Some(done) = find_published_job(root, job_id)? {
            LOG.warn(&format!(
                "stream publish: job {job_id} already committed generation {} — \
                 returning it instead of appending the stream twice",
                done.generation
            ));
            return Ok(done);
        }
    }
    let tick = || {
        if let Some(p) = &opts.progress {
            p();
        }
    };
    let store = ModelStore::open(root, 1)?;
    let n = store.n();
    if result.n != n {
        return Err(Error::shape(format!(
            "stream publish: stream factors have n={}, model n={n} — run the stream \
             with .cols({n}) so the dictionaries align",
            result.n
        )));
    }
    if store.centered() != result.means.is_some() {
        return Err(Error::Config(format!(
            "stream publish: model is {}centered but the stream ran {}centered — \
             set .center({}) on the stream",
            if store.centered() { "" } else { "un" },
            if result.means.is_some() { "" } else { "un" },
            store.centered()
        )));
    }
    let v1 = result
        .v
        .as_ref()
        .ok_or_else(|| Error::Config("stream publish: stream result carries no V".into()))?;
    let mut report = PhaseReport::new();

    let t0 = Instant::now();
    tick();
    let merged = merge_factored(
        &FactoredBlock { sigma: store.sigma(), v: store.v(), m: store.m(), mu: store.means() },
        &FactoredBlock { sigma: &result.sigma, v: v1, m: result.m, mu: result.means.as_deref() },
        opts.rank.unwrap_or(store.k()),
        backend,
    )?;
    let k_new = merged.sigma.len();
    report.push("leader.merge_factored", t0.elapsed(), (store.k() + result.k) as u64, 0);

    let t0 = Instant::now();
    let next = next_generation(root, store.generation())?;
    let gen_dir = root.join(generation_dir_name(next));
    begin_generation(&gen_dir)?;

    let sigma_text: String = merged.sigma.iter().map(|s| format!("{s}\n")).collect();
    std::fs::write(gen_dir.join("sigma.csv"), sigma_text)?;
    let v_path = gen_dir.join("V.bin").to_string_lossy().into_owned();
    crate::io::binmat::write_matrix_bin(&merged.v_new, &v_path)?;
    if let Some(mu) = &merged.means {
        let mrow = Matrix::from_rows(std::slice::from_ref(mu))?;
        let m_path = gen_dir.join("means.bin").to_string_lossy().into_owned();
        crate::io::binmat::write_matrix_bin(&mrow, &m_path)?;
    }

    let dst = ShardSet::new(&gen_dir, "U", InputFormat::Bin)?;
    let norms_path = gen_dir.join("norms.bin").to_string_lossy().into_owned();
    let mut norms =
        crate::io::binmat::BinMatWriter::create(&norms_path, 1, crate::io::binmat::DType::F64)?;
    let mut shard_rows = Vec::with_capacity(store.shards() + result.shards);
    let mut total = 0usize;
    for i in 0..store.shards() {
        let count = rotate_shard(
            store.u_shard_reader(i)?,
            dst.open_writer(i, k_new)?,
            &merged.p_old,
            merged.old_offset.as_deref(),
            &merged.sigma,
            &mut norms,
            &format!("parent U shard {i}"),
        )?;
        shard_rows.push(count);
        total += count;
        tick();
    }
    for i in 0..result.shards {
        let count = rotate_shard(
            result.u_shards.open_reader(i)?,
            dst.open_writer(store.shards() + i, k_new)?,
            &merged.p_new,
            merged.new_offset.as_deref(),
            &merged.sigma,
            &mut norms,
            &format!("stream U shard {i}"),
        )?;
        shard_rows.push(count);
        total += count;
        tick();
    }
    norms.finish()?;
    if total != store.m() + result.m {
        return Err(Error::Other(format!(
            "stream publish: generation holds {total} rows, expected {}",
            store.m() + result.m
        )));
    }

    let mut man = model_manifest(
        total,
        n,
        k_new,
        &shard_rows,
        merged.means.is_some(),
        next,
        Some(store.generation()),
        opts.seed,
    );
    if let Some(job_id) = opts.job_id {
        man.set("stream_job_id", job_id);
        man.set("stream_rows_added", result.m);
    }
    man.save(gen_dir.join("model.manifest"))?;
    publish_generation(root, next)?;
    report.push("leader.write_generation", t0.elapsed(), total as u64, 0);
    // Committed; GC is best-effort from here — a "failed" retry would
    // append the same stream twice.
    if let Err(e) = gc_generations(root, opts.keep_generations.max(1)) {
        LOG.warn(&format!("post-publish gc failed (non-fatal): {e}"));
    }
    let reg = MetricsRegistry::global();
    reg.add("update_rows", result.m as f64);
    reg.add("stream_publishes", 1.0);
    LOG.info(&format!(
        "stream publish: generation {next} serves {total}x{n} k={k_new} \
         (+{} streamed rows)",
        result.m
    ));
    Ok(UpdateResult {
        generation: next,
        dir: gen_dir,
        m: total,
        n,
        k: k_new,
        rows_added: result.m,
        sigma: merged.sigma,
        report,
    })
}

/// Scan committed generations for one already published by `job_id` (see
/// [`StreamPublish::job_id`]). Half-written generation dirs have no
/// manifest and are skipped.
fn find_published_job(root: &Path, job_id: u64) -> Result<Option<UpdateResult>> {
    for (generation, dir) in list_generations(root)? {
        let Ok(man) = KvManifest::load(dir.join("model.manifest")) else { continue };
        if man.get_u64("stream_job_id").ok().flatten() != Some(job_id) {
            continue;
        }
        let m = man.require_usize("m")?;
        let n = man.require_usize("n")?;
        let k = man.require_usize("k")?;
        let rows_added = man
            .get_u64("stream_rows_added")?
            .ok_or_else(|| Error::parse("generation manifest: missing stream_rows_added"))?
            as usize;
        let sigma = std::fs::read_to_string(dir.join("sigma.csv"))
            .map_err(|e| Error::Other(format!("cannot read {}/sigma.csv: {e}", dir.display())))?
            .lines()
            .map(|l| {
                l.trim().parse().map_err(|_| {
                    Error::parse(format!("{}: sigma.csv: bad value `{l}`", dir.display()))
                })
            })
            .collect::<Result<Vec<f64>>>()?;
        return Ok(Some(UpdateResult {
            generation,
            dir,
            m,
            n,
            k,
            rows_added,
            sigma,
            report: PhaseReport::new(),
        }));
    }
    Ok(None)
}

/// Stream one `U` shard through a `k x k'` rotation (plus the centered
/// per-row offset), block-buffered into one matmul per slab, appending each
/// rotated row's embedding norm to the sidecar. Returns the row count.
fn rotate_shard(
    mut reader: ShardReader,
    mut writer: ShardWriter,
    p: &Matrix,
    offset: Option<&[f64]>,
    sigma: &[f64],
    norms: &mut crate::io::binmat::BinMatWriter,
    what: &str,
) -> Result<usize> {
    const ROTATE_BLOCK: usize = 512;
    let mut row = Vec::new();
    let mut buf: Vec<Vec<f64>> = Vec::with_capacity(ROTATE_BLOCK);
    let mut count = 0usize;
    loop {
        buf.clear();
        while buf.len() < ROTATE_BLOCK {
            if !reader.next_row(&mut row)? {
                break;
            }
            if row.len() != p.rows() {
                return Err(Error::shape(format!(
                    "stream publish: {what} row has {} cols, expected {}",
                    row.len(),
                    p.rows()
                )));
            }
            buf.push(row.clone());
        }
        if buf.is_empty() {
            break;
        }
        let slab = Matrix::from_rows(&buf)?;
        let mut rotated = matmul(&slab, p)?;
        if let Some(off) = offset {
            for rix in 0..rotated.rows() {
                for (v, o) in rotated.row_mut(rix).iter_mut().zip(off.iter()) {
                    *v += o;
                }
            }
        }
        for rix in 0..rotated.rows() {
            let urow = rotated.row(rix);
            writer.write_row(urow)?;
            norms.write_row(&[embedding_norm(urow, sigma)])?;
        }
        count += rotated.rows();
        if buf.len() < ROTATE_BLOCK {
            break;
        }
    }
    writer.finish()?;
    Ok(count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::io::dataset::{gen_exact, Spectrum};
    use crate::io::InputSpec;
    use crate::stream::StreamSvd;
    use std::sync::Arc;

    fn tmp_dir(name: &str) -> String {
        let dir = std::env::temp_dir().join("tallfat_test_stream_pub").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir.to_string_lossy().into_owned()
    }

    /// Factor 60 rows the multi-pass way into a model, stream 40 more rows
    /// into a factorization, publish the merge, and check the published
    /// generation against a direct factorization of all 100 rows.
    #[test]
    fn stream_publish_matches_full_factorization() {
        let (m0, m1, n, rank) = (60usize, 40usize, 12usize, 4usize);
        let backend: BackendRef = Arc::new(NativeBackend::new());
        let (a, _) =
            gen_exact(m0 + m1, n, rank, Spectrum::Geometric { scale: 8.0, decay: 0.6 }, 0.0, 5)
                .unwrap();

        // Base model from the first m0 rows.
        let base_path = tmp_dir("base_rows");
        let base_csv = format!("{base_path}/a0.csv");
        crate::io::csv::write_matrix_csv(&a.slice_rows(0, m0), &base_csv).unwrap();
        let model_dir = tmp_dir("model");
        crate::svd::Svd::over(&InputSpec::csv(&base_csv))
            .unwrap()
            .rank(rank)
            .work_dir(tmp_dir("base_work"))
            .save_model(&model_dir)
            .run()
            .unwrap();

        // Stream the remaining rows (rank pinned: parity mode).
        let tail_csv = format!("{base_path}/a1.csv");
        crate::io::csv::write_matrix_csv(&a.slice_rows(m0, m0 + m1), &tail_csv).unwrap();
        let streamed = StreamSvd::open(&tail_csv)
            .rank(rank)
            .cols(n)
            .batch_rows(16)
            .work_dir(tmp_dir("stream_work"))
            .run()
            .unwrap();
        assert_eq!(streamed.m, m1);

        let out = publish_stream_result(
            &model_dir,
            &streamed,
            &backend,
            &StreamPublish { rank: Some(rank), ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.m, m0 + m1);
        assert_eq!(out.rows_added, m1);

        // The published generation loads and reconstructs all rows.
        let store = ModelStore::open(&model_dir, 1).unwrap();
        assert_eq!(store.generation(), out.generation);
        assert_eq!(store.m(), m0 + m1);
        let mut u_rows = Vec::with_capacity(store.m());
        for i in 0..store.m() {
            u_rows.push(store.u_row(i).unwrap());
        }
        let u = Matrix::from_rows(&u_rows).unwrap();
        let recon = matmul(&u.scale_cols(store.sigma()).unwrap(), &store.v().t()).unwrap();
        let rel = recon.max_abs_diff(&a) / a.max_abs();
        assert!(rel < 1e-5, "published generation reconstruction rel err {rel}");
    }

    /// A retried publish carrying the same job id (a reaped-but-alive
    /// predecessor already committed) must return the existing generation
    /// instead of appending the streamed rows a second time.
    #[test]
    fn stream_publish_is_idempotent_per_job_id() {
        let (m0, m1, n, rank) = (30usize, 20usize, 8usize, 3usize);
        let backend: BackendRef = Arc::new(NativeBackend::new());
        let (a, _) =
            gen_exact(m0 + m1, n, rank, Spectrum::Geometric { scale: 4.0, decay: 0.5 }, 0.0, 7)
                .unwrap();
        let base = tmp_dir("idem_rows");
        let base_csv = format!("{base}/a0.csv");
        crate::io::csv::write_matrix_csv(&a.slice_rows(0, m0), &base_csv).unwrap();
        let model_dir = tmp_dir("idem_model");
        crate::svd::Svd::over(&InputSpec::csv(&base_csv))
            .unwrap()
            .rank(rank)
            .work_dir(tmp_dir("idem_work"))
            .save_model(&model_dir)
            .run()
            .unwrap();
        let tail_csv = format!("{base}/a1.csv");
        crate::io::csv::write_matrix_csv(&a.slice_rows(m0, m0 + m1), &tail_csv).unwrap();
        let streamed = StreamSvd::open(&tail_csv)
            .rank(rank)
            .cols(n)
            .work_dir(tmp_dir("idem_stream_work"))
            .run()
            .unwrap();

        let opts = StreamPublish {
            rank: Some(rank),
            job_id: Some(42),
            ..Default::default()
        };
        let first = publish_stream_result(&model_dir, &streamed, &backend, &opts).unwrap();
        assert_eq!(first.m, m0 + m1);
        let second = publish_stream_result(&model_dir, &streamed, &backend, &opts).unwrap();
        assert_eq!(second.generation, first.generation, "retry must reuse the generation");
        assert_eq!(second.m, first.m);
        assert_eq!(second.rows_added, first.rows_added);
        assert_eq!(second.k, first.k);
        assert_eq!(second.sigma, first.sigma);
        let store = ModelStore::open(&model_dir, 1).unwrap();
        assert_eq!(store.generation(), first.generation);
        assert_eq!(store.m(), m0 + m1, "rows must not be appended twice");

        // A different job id is a genuinely new publish.
        let other = StreamPublish {
            rank: Some(rank),
            job_id: Some(43),
            ..Default::default()
        };
        let third = publish_stream_result(&model_dir, &streamed, &backend, &other).unwrap();
        assert_eq!(third.generation, first.generation + 1);
        assert_eq!(third.m, m0 + 2 * m1);
    }

    #[test]
    fn stream_publish_rejects_centering_mismatch() {
        let (m0, m1, n, rank) = (30usize, 20usize, 8usize, 3usize);
        let backend: BackendRef = Arc::new(NativeBackend::new());
        let (a, _) =
            gen_exact(m0 + m1, n, rank, Spectrum::Geometric { scale: 4.0, decay: 0.5 }, 0.0, 9)
                .unwrap();
        let base = tmp_dir("mismatch_rows");
        let base_csv = format!("{base}/a0.csv");
        crate::io::csv::write_matrix_csv(&a.slice_rows(0, m0), &base_csv).unwrap();
        let model_dir = tmp_dir("mismatch_model");
        crate::svd::Svd::over(&InputSpec::csv(&base_csv))
            .unwrap()
            .rank(rank)
            .work_dir(tmp_dir("mismatch_work"))
            .save_model(&model_dir)
            .run()
            .unwrap();
        let tail_csv = format!("{base}/a1.csv");
        crate::io::csv::write_matrix_csv(&a.slice_rows(m0, m0 + m1), &tail_csv).unwrap();
        let streamed = StreamSvd::open(&tail_csv)
            .rank(rank)
            .cols(n)
            .center(true) // model is uncentered
            .work_dir(tmp_dir("mismatch_stream_work"))
            .run()
            .unwrap();
        assert!(publish_stream_result(
            &model_dir,
            &streamed,
            &backend,
            &StreamPublish::default()
        )
        .is_err());
    }
}
