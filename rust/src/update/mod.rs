//! Incremental rank-k model updates — append rows to a saved factorization
//! without re-reading the original input.
//!
//! A factorization frozen at `tallfat svd --save-model` time goes stale the
//! moment new rows exist. Re-running the full pipeline over the entire
//! input scales with *all* rows ever seen; this subsystem scales with the
//! *batch*:
//!
//! ```text
//! pass 0  μ' = merge(μ₀, colsums(A₁))   centered models only     (over A₁)
//! pass 1  Y = A₁ [V | (I-VVᵀ)Ω]         project + gram G = YᵀY   (over A₁)
//! leader  orth(Y_r)                      r x r eigh -> M_r
//! pass 2  [B | U_h] = Y M₂, W = A₁ᵀ·     completion partial       (over A₁)
//! leader  merge-and-truncate             QR + (k+r)² eigh -> Σ', V', P_old, P_new
//! pass 3  U₁ = [B | U_h] P_new           shard rotation           (over shards)
//! leader  U₀' = U₀ P_old (+ offset)      stream-rotate old shards
//!         write generation g+1, repoint CURRENT, GC old generations
//! ```
//!
//! The streaming passes are the *same* [`crate::svd::Pass`] descriptions
//! the factorization pipeline uses, driven through the same
//! [`crate::svd::Executor`] seam — so updates run on in-process threads or
//! on a remote cluster with zero new worker code. All dense math on the
//! leader stays `O((k+r)²)`–`O((k+r)³)` (Halko et al.'s block-wise range
//! finder composed with a Zha–Simon merge; see [`merge`] for the algebra).
//!
//! The output is a new *generation* in the model root ([`crate::serve::store`]):
//! immutable, committed by its manifest, published by an atomic `CURRENT`
//! rename — which is what lets a serving process hot-swap to it with zero
//! downtime ([`crate::serve::query::EngineHandle`]).
//!
//! Entry point: the [`Update`] builder, symmetric with [`crate::svd::Svd`]:
//!
//! ```ignore
//! let next = Update::of("/models/m1")?.rows(&batch).executor(&mut e).run()?;
//! ```
//!
//! Rows that arrive over a pipe (and so cannot be re-read by the passes
//! above) take the streaming route instead: factor them in one pass with
//! [`crate::stream::StreamSvd`], then fold the finished factors into the
//! model with [`publish_stream_result`] — a [`merge_factored`] of two
//! already-orthonormal blocks followed by the same generation commit.

pub mod builder;
pub mod merge;
pub mod stream;

pub use builder::{Update, UpdateResult};
pub use merge::{merge_factored, merge_truncate, FactoredBlock, MergeInput, MergeOutput};
pub use stream::{publish_stream_result, StreamPublish};
