//! The fluent entry point for incremental model updates — symmetric with
//! [`crate::svd::Svd`]:
//!
//! ```no_run
//! use tallfat::io::InputSpec;
//! use tallfat::update::Update;
//!
//! # fn main() -> tallfat::Result<()> {
//! let batch = InputSpec::csv("/data/new_rows.csv");
//! let next = Update::of("/models/m1")?    // resolves the live generation
//!     .rows(&batch)
//!     .oversample(8)
//!     .run()?;                            // LocalExecutor by default
//! println!("generation {} now serves {} rows", next.generation, next.m);
//! # Ok(())
//! # }
//! ```
//!
//! Swap the execution substrate exactly like the factorization builder:
//!
//! ```ignore
//! let mut cluster = ClusterExecutor::accept("0.0.0.0:7070", 8)?;
//! let next = Update::of(dir)?.rows(&batch).executor(&mut cluster).run()?;
//! ```

use crate::backend::native::NativeBackend;
use crate::backend::BackendRef;
use crate::config::InputFormat;
use crate::coordinator::server::MetricsRegistry;
use crate::error::{Error, Result};
use crate::io::manifest::KvManifest;
use crate::io::writer::ShardSet;
use crate::io::InputSpec;
use crate::linalg::{matmul, matmul_tn, Matrix};
use crate::metrics::PhaseReport;
use crate::rng::VirtualMatrix;
use crate::serve::store::{
    begin_generation, embedding_norm, gc_generations, generation_dir_name, next_generation,
    publish_generation, ModelStore,
};
use crate::svd::executor::{Executor, LocalExecutor, Pass, PassContext};
use crate::svd::pipeline::guarded_inverse;
use crate::update::merge::{merge_truncate, MergeInput, MergeOutput};
use crate::util::Logger;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

static LOG: Logger = Logger::new("update");

/// Outcome of one incremental update: the next generation's identity and
/// factors summary.
pub struct UpdateResult {
    /// Generation number written (past the parent and everything on disk).
    pub generation: u64,
    /// The new generation's directory.
    pub dir: PathBuf,
    /// Total rows served by the new generation.
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Rows appended by this update (0 for a no-op generation).
    pub rows_added: usize,
    /// New singular values, descending.
    pub sigma: Vec<f64>,
    /// Phase timing of the update.
    pub report: PhaseReport,
}

/// Builder for one incremental update of a saved model (see module docs).
pub struct Update<'a> {
    root: PathBuf,
    store: ModelStore,
    input: Option<InputSpec>,
    rank: Option<usize>,
    oversample: usize,
    workers: usize,
    block: usize,
    seed: u64,
    work_dir: String,
    /// True while `work_dir` is the builder's own unique scratch default —
    /// such a directory is deleted after a successful run (it would leak
    /// one directory per update otherwise); caller-provided dirs are kept.
    own_work_dir: bool,
    sigma_cutoff_rel: f64,
    keep_generations: usize,
    sched: crate::splitproc::SchedPolicy,
    backend: Option<BackendRef>,
    executor: Option<&'a mut dyn Executor>,
}

impl<'a> Update<'a> {
    /// Start an update of the model at `dir`. Resolves and loads the live
    /// generation eagerly so a missing or damaged model fails here, once.
    pub fn of(dir: impl AsRef<Path>) -> Result<Self> {
        let root = dir.as_ref().to_path_buf();
        // Guard against being handed a *generation* directory instead of
        // the model root: ModelStore::open would resolve it (flat-layout
        // fallback), and the update would then nest a new generation
        // inside the immutable gen dir while the real root's CURRENT
        // never advances — a silent no-op for every serving reader.
        if let Some(name) = root.file_name().and_then(|n| n.to_str()) {
            if name.strip_prefix("gen-").is_some_and(|s| s.parse::<u64>().is_ok()) {
                return Err(Error::Config(format!(
                    "update: `{}` is a generation directory, not a model root — \
                     point the update at its parent",
                    root.display()
                )));
            }
        }
        let store = ModelStore::open(&root, 1)?;
        // Unlike a factorization (whose output is just this run's result),
        // an update's shards feed a generation of an existing persisted
        // model — a shared scratch directory would let two concurrent
        // updates corrupt each other, so the default is per-process and
        // per-invocation.
        static WORK_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = WORK_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(Update {
            root,
            store,
            input: None,
            rank: None,
            oversample: 8,
            workers: 4,
            block: 256,
            seed: 1,
            work_dir: std::env::temp_dir()
                .join(format!("tallfat_update_{}_{seq}", std::process::id()))
                .to_string_lossy()
                .into_owned(),
            own_work_dir: true,
            sigma_cutoff_rel: crate::svd::DEFAULT_SIGMA_CUTOFF_REL,
            keep_generations: 2,
            sched: crate::splitproc::SchedPolicy::default(),
            backend: None,
            executor: None,
        })
    }

    /// The generation the update will build on.
    pub fn parent_generation(&self) -> u64 {
        self.store.generation()
    }

    /// The new tall-and-fat row batch to append (required).
    pub fn rows(mut self, input: &InputSpec) -> Self {
        self.input = Some(input.clone());
        self
    }

    /// Rank of the next generation (default: keep the model's k; capped at
    /// the merged basis width `k + r`).
    pub fn rank(mut self, k: usize) -> Self {
        self.rank = Some(k);
        self
    }

    /// Residual-sketch oversampling: the update captures up to
    /// `k + oversample` new row-space directions from the batch.
    pub fn oversample(mut self, p: usize) -> Self {
        self.oversample = p;
        self
    }

    /// Split-Process worker count (the default [`LocalExecutor`] fan-out).
    pub fn workers(mut self, w: usize) -> Self {
        self.workers = w;
        self
    }

    /// Row-block size fed to the block backend.
    pub fn block(mut self, rows: usize) -> Self {
        self.block = rows;
        self
    }

    /// PRNG seed for the residual sketch Ω.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Directory for the intermediate Y/U0/U shards. Defaults to a unique
    /// per-invocation temp directory that is removed after a successful
    /// run; a directory set here is left in place.
    pub fn work_dir(mut self, dir: impl Into<String>) -> Self {
        self.work_dir = dir.into();
        self.own_work_dir = false;
        self
    }

    /// Relative cutoff for the residual sketch's guarded inverse.
    pub fn sigma_cutoff_rel(mut self, cutoff: f64) -> Self {
        self.sigma_cutoff_rel = cutoff;
        self
    }

    /// How many generations survive garbage collection after the update
    /// (min 1; default 2 so in-flight readers of the parent finish).
    pub fn keep_generations(mut self, keep: usize) -> Self {
        self.keep_generations = keep.max(1);
        self
    }

    /// Cap scheduler chunks at `rows` rows each (0 = derive the chunk
    /// count from [`Update::chunks_per_worker`] instead).
    pub fn chunk_rows(mut self, rows: usize) -> Self {
        self.sched.chunk_rows = rows;
        self
    }

    /// Chunks planned per worker (default
    /// [`crate::splitproc::sched::DEFAULT_CHUNKS_PER_WORKER`]).
    pub fn chunks_per_worker(mut self, chunks: usize) -> Self {
        self.sched.chunks_per_worker = chunks;
        self
    }

    /// Retry budget per chunk before a pass fails (default
    /// [`crate::splitproc::sched::DEFAULT_CHUNK_RETRIES`]).
    pub fn chunk_retries(mut self, retries: usize) -> Self {
        self.sched.max_retries = retries;
        self
    }

    /// Block-compute backend for leader math and (local) worker jobs.
    pub fn backend(mut self, backend: BackendRef) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Execution substrate for the streaming passes over the new rows.
    pub fn executor(mut self, exec: &'a mut dyn Executor) -> Self {
        self.executor = Some(exec);
        self
    }

    /// Run the update: stream the batch, merge-and-truncate on the leader,
    /// write the next generation, repoint `CURRENT`, GC old generations.
    pub fn run(self) -> Result<UpdateResult> {
        let input = self
            .input
            .clone()
            .ok_or_else(|| Error::Config("update: no row batch (call .rows(&input))".into()))?;
        if self.workers == 0 || self.block == 0 {
            return Err(Error::Config("update: workers and block must be >= 1".into()));
        }
        if self.rank == Some(0) {
            return Err(Error::Config("update: rank must be >= 1".into()));
        }
        if !(0.0..1.0).contains(&self.sigma_cutoff_rel) {
            return Err(Error::Config(format!(
                "update: sigma_cutoff_rel must be in [0, 1), got {}",
                self.sigma_cutoff_rel
            )));
        }
        if self.sched.chunks_per_worker == 0 {
            return Err(Error::Config("update: chunks_per_worker must be >= 1".into()));
        }
        let (m1, n1) = input.dims()?;
        if m1 == 0 {
            // An empty batch commits a no-op generation: same factors, next
            // number — so "the update ran" is observable and replayable.
            return self.noop_generation();
        }
        // Sparse text batches report cols = highest referenced column + 1,
        // which may undershoot the model's n when the batch doesn't touch
        // the trailing columns — that is still a valid batch.
        if n1 != self.store.n() && !(input.format.is_sparse() && n1 <= self.store.n()) {
            return Err(Error::shape(format!(
                "update: batch has {n1} cols, model n={}",
                self.store.n()
            )));
        }
        let backend = self
            .backend
            .clone()
            .unwrap_or_else(|| Arc::new(NativeBackend::new()));
        let opts = UpdateOptions::of(&self);
        let mut this = self;
        match this.executor.take() {
            Some(exec) => {
                run_update(exec, &this.store, &this.root, &input, m1, backend, &opts)
            }
            None => {
                let mut local = LocalExecutor::new(this.workers);
                run_update(&mut local, &this.store, &this.root, &input, m1, backend, &opts)
            }
        }
    }

    /// Write the next generation as a verbatim copy of the parent.
    fn noop_generation(self) -> Result<UpdateResult> {
        let store = &self.store;
        let next = next_generation(&self.root, store.generation())?;
        let gen_dir = self.root.join(generation_dir_name(next));
        begin_generation(&gen_dir)?;
        let mut names = vec!["sigma.csv".to_string(), "V.bin".into(), "norms.bin".into()];
        if store.centered() {
            names.push("means.bin".into());
        }
        for i in 0..store.shards() {
            names.push(format!("U-{i}.bin"));
        }
        for name in names {
            std::fs::copy(store.dir().join(&name), gen_dir.join(&name))?;
        }
        let mut man = KvManifest::load(store.dir().join("model.manifest"))?;
        man.set("generation", next);
        man.set("updated_from", store.generation());
        man.save(gen_dir.join("model.manifest"))?;
        publish_generation(&self.root, next)?;
        // Committed; GC is best-effort from here (see run_update).
        if let Err(e) = gc_generations(&self.root, self.keep_generations) {
            LOG.warn(&format!("post-publish gc failed (non-fatal): {e}"));
        }
        LOG.info(&format!(
            "empty batch: generation {next} is a no-op copy of {}",
            store.generation()
        ));
        MetricsRegistry::global().add("update_rows", 0.0);
        Ok(UpdateResult {
            generation: next,
            dir: gen_dir,
            m: store.m(),
            n: store.n(),
            k: store.k(),
            rows_added: 0,
            sigma: store.sigma().to_vec(),
            report: PhaseReport::new(),
        })
    }
}

/// The plain-value view of the builder the driver needs (so the executor
/// borrow can be split off).
struct UpdateOptions {
    rank: Option<usize>,
    oversample: usize,
    block: usize,
    seed: u64,
    work_dir: String,
    own_work_dir: bool,
    sigma_cutoff_rel: f64,
    keep_generations: usize,
    sched: crate::splitproc::SchedPolicy,
}

impl UpdateOptions {
    fn of(u: &Update) -> Self {
        UpdateOptions {
            rank: u.rank,
            oversample: u.oversample,
            block: u.block,
            seed: u.seed,
            work_dir: u.work_dir.clone(),
            own_work_dir: u.own_work_dir,
            sigma_cutoff_rel: u.sigma_cutoff_rel,
            keep_generations: u.keep_generations,
            sched: u.sched,
        }
    }
}

/// The update driver: three executor passes over the batch, one small
/// leader merge, then the generation rewrite. Mirrors
/// [`crate::svd::pipeline::run_svd`]'s structure.
fn run_update(
    exec: &mut dyn Executor,
    store: &ModelStore,
    root: &Path,
    input: &InputSpec,
    m1: usize,
    backend: BackendRef,
    opts: &UpdateOptions,
) -> Result<UpdateResult> {
    let (m0, n, k) = (store.m(), store.n(), store.k());
    // Residual sketch width: at most `oversample + k` genuinely new
    // directions exist worth keeping, never more than the batch has rows or
    // the row space has room for.
    let r = (k + opts.oversample).min(n - k).min(m1);
    let k_new = opts.rank.unwrap_or(k);
    let mut report = PhaseReport::new();
    let mut ctx = PassContext {
        input,
        backend,
        work_dir: &opts.work_dir,
        shard_format: InputFormat::Bin,
        block: opts.block,
        seed: opts.seed,
        n,
        kp: k + r,
        means: Arc::new(Vec::new()),
        // Updates inherit dynamic chunk scheduling through the executor
        // seam: batch passes are planned fine-grained and retried exactly
        // like a factorization's, under the builder's knobs.
        sched: opts.sched,
        shard_epoch: 0,
        // Update passes reduce k'-scale partials only; the sequential fold
        // keeps generation N+1 bitwise-reproducible against pre-tree runs.
        reduce: crate::svd::reduce::ReduceMode::Star,
        band_rows: 0,
    };
    LOG.info(&format!(
        "update gen {}: {m0}x{n} k={k} + {m1} rows (residual sketch {r}), executor={}",
        store.generation(),
        exec.name()
    ));
    std::fs::create_dir_all(&opts.work_dir)?;
    // Clear staged-shard litter from earlier crashed runs of this work
    // dir (no writers are active yet, so the sweep cannot race one).
    crate::io::writer::sweep_stale_stages(&opts.work_dir);

    // ---- pass 0 (PCA models): batch column sums -> merged running mean --
    let mut means_new: Option<Vec<f64>> = None;
    let mut c0: Option<Vec<f64>> = None;
    if let Some(mu0) = store.means() {
        let t0 = Instant::now();
        let out = exec.run_pass(&ctx, &Pass::ColStats)?;
        check_rows(out.rows, m1, "pass0")?;
        let sums = out
            .partial
            .ok_or_else(|| Error::Other("update pass0 returned no colstats partial".into()))?;
        let m_total = (m0 + m1) as f64;
        let mu_new: Vec<f64> = (0..n)
            .map(|j| (m0 as f64 * mu0[j] + sums.get(0, j)) / m_total)
            .collect();
        c0 = Some((0..n).map(|j| mu0[j] - mu_new[j]).collect());
        ctx.means = Arc::new(mu_new.clone());
        means_new = Some(mu_new);
        report.push("pass0.colstats", t0.elapsed(), out.rows, 0);
    }

    // ---- pass 1: Y = A₁ [V | (I - VVᵀ)Ω], G = YᵀY ------------------------
    let t0 = Instant::now();
    let v = store.v();
    let mut omega_c = Matrix::zeros(n, k + r);
    for i in 0..n {
        for j in 0..k {
            omega_c.set(i, j, v.get(i, j));
        }
    }
    if r > 0 {
        let omega = VirtualMatrix::projection(opts.seed, n, r).materialize();
        let vt_om = matmul_tn(v, &omega)?;
        let v_vt_om = matmul(v, &vt_om)?;
        for i in 0..n {
            for j in 0..r {
                omega_c.set(i, k + j, omega.get(i, j) - v_vt_om.get(i, j));
            }
        }
    }
    let out1 = exec.run_pass(&ctx, &Pass::ProjectGram { omega: Some(&omega_c) })?;
    check_rows(out1.rows, m1, "pass1")?;
    let new_shards = out1.shards;
    let g = out1
        .partial
        .ok_or_else(|| Error::Other("update pass1 returned no gram partial".into()))?;
    report.push("pass1.project_gram", t0.elapsed(), out1.rows, 0);

    // ---- leader: orthonormalize the residual sketch ----------------------
    let t0 = Instant::now();
    let m_r = if r > 0 {
        let g_rr = Matrix::from_fn(r, r, |i, j| g.get(k + i, k + j));
        let (w_eig, v_y) = ctx.backend.eigh(&g_rr)?;
        let sig_y: Vec<f64> = w_eig.iter().map(|&w| w.max(0.0).sqrt()).collect();
        v_y.scale_cols(&guarded_inverse(&sig_y, opts.sigma_cutoff_rel))?
    } else {
        Matrix::zeros(0, 0)
    };
    let mut m2 = Matrix::zeros(k + r, k + r);
    for i in 0..k {
        m2.set(i, i, 1.0);
    }
    for i in 0..r {
        for j in 0..r {
            m2.set(k + i, k + j, m_r.get(i, j));
        }
    }
    report.push("leader.eigh_residual", t0.elapsed(), r as u64, 0);

    // ---- pass 2: U0 shards = [B | U_h], W = A₁ᵀ [B | U_h] ----------------
    let t0 = Instant::now();
    let out2 = exec.run_pass(&ctx, &Pass::UrecoverTmul { m: &m2 })?;
    check_rows(out2.rows, m1, "pass2")?;
    let w = out2
        .partial
        .ok_or_else(|| Error::Other("update pass2 returned no W partial".into()))?;
    let w_h = w.slice_cols(k, k + r);
    report.push("pass2.urecover_tmul", t0.elapsed(), out2.rows, 0);

    // ---- leader: merge-and-truncate (the (k+r)² eigensolve) --------------
    let t0 = Instant::now();
    let merged = merge_truncate(
        &MergeInput {
            sigma0: store.sigma(),
            v,
            gram: &g,
            w_h: &w_h,
            m_r: &m_r,
            m0,
            c0: c0.as_deref(),
        },
        k_new,
        &ctx.backend,
    )?;
    let merge_elapsed = t0.elapsed();
    report.push("leader.merge_truncate", merge_elapsed, (k + r) as u64, 0);

    // ---- pass 3: rotate the batch's [B | U_h] shards into U --------------
    let t0 = Instant::now();
    let out3 = exec.run_pass(&ctx, &Pass::RotateU { p: &merged.p_new })?;
    report.push("pass3.rotate_u", t0.elapsed(), out3.rows, 0);

    // ---- leader: write the next generation -------------------------------
    let t0 = Instant::now();
    // Numbered past everything on disk, not just past the parent: if
    // CURRENT was rolled back, the abandoned newer generations stay
    // immutable for readers that still hold them open.
    let next = next_generation(root, store.generation())?;
    let gen_dir = root.join(generation_dir_name(next));
    let total_rows = write_generation(
        store,
        &gen_dir,
        next,
        &merged,
        means_new.as_deref(),
        &opts.work_dir,
        new_shards,
        opts.seed,
    )?;
    if total_rows != m0 + m1 {
        return Err(Error::Other(format!(
            "update: generation holds {total_rows} rows, expected {}",
            m0 + m1
        )));
    }
    publish_generation(root, next)?;
    // CURRENT is repointed: the update is committed. Everything after is
    // best-effort cleanup — a GC hiccup must not fail the run (a "failed"
    // retry would append the same batch twice).
    if let Err(e) = gc_generations(root, opts.keep_generations) {
        LOG.warn(&format!("post-publish gc failed (non-fatal): {e}"));
    }
    if opts.own_work_dir {
        // The default scratch dir is unique per invocation — remove it or
        // every update would leak a batch's worth of shards in temp.
        let _ = std::fs::remove_dir_all(&opts.work_dir);
    }
    report.push("leader.write_generation", t0.elapsed(), total_rows as u64, 0);

    let reg = MetricsRegistry::global();
    reg.add("update_rows", m1 as f64);
    reg.set("update_merge_ms", merge_elapsed.as_secs_f64() * 1e3);
    LOG.info(&format!(
        "update done: generation {next} serves {}x{n} k={} (sigma[0]={:.4})",
        m0 + m1,
        merged.sigma.len(),
        merged.sigma.first().copied().unwrap_or(0.0)
    ));
    Ok(UpdateResult {
        generation: next,
        dir: gen_dir,
        m: m0 + m1,
        n,
        k: merged.sigma.len(),
        rows_added: m1,
        sigma: merged.sigma,
        report,
    })
}

fn check_rows(got: u64, want: usize, pass: &str) -> Result<()> {
    if got as usize != want {
        return Err(Error::Other(format!(
            "update {pass} saw {got} rows, expected {want}"
        )));
    }
    Ok(())
}

/// Write the next generation directory: rotated old U shards (plus the
/// centered row offset), the batch's freshly rotated shards appended after
/// them, the new small factors, the norms sidecar, and the manifest last.
/// Returns the total row count written.
#[allow(clippy::too_many_arguments)]
fn write_generation(
    store: &ModelStore,
    gen_dir: &Path,
    generation: u64,
    merged: &MergeOutput,
    means_new: Option<&[f64]>,
    work_dir: &str,
    new_shards: usize,
    seed: u64,
) -> Result<usize> {
    let k_new = merged.sigma.len();
    begin_generation(gen_dir)?;

    let sigma_text: String = merged.sigma.iter().map(|s| format!("{s}\n")).collect();
    std::fs::write(gen_dir.join("sigma.csv"), sigma_text)?;
    let v_path = gen_dir.join("V.bin").to_string_lossy().into_owned();
    crate::io::binmat::write_matrix_bin(&merged.v_new, &v_path)?;
    if let Some(mu) = means_new {
        let mrow = Matrix::from_rows(std::slice::from_ref(&mu.to_vec()))?;
        let m_path = gen_dir.join("means.bin").to_string_lossy().into_owned();
        crate::io::binmat::write_matrix_bin(&mrow, &m_path)?;
    }

    let dst = ShardSet::new(gen_dir, "U", InputFormat::Bin)?;
    let norms_path = gen_dir.join("norms.bin").to_string_lossy().into_owned();
    let mut norms =
        crate::io::binmat::BinMatWriter::create(&norms_path, 1, crate::io::binmat::DType::F64)?;
    let mut shard_rows = Vec::with_capacity(store.shards() + new_shards);
    let mut total = 0usize;

    // Old rows: stream each parent shard through the k x k' rotation,
    // block-buffered into one matmul per slab (the same shape of work as
    // the executor's `rotate_one_shard`), then the centered offset and the
    // norms sidecar per row.
    const ROTATE_BLOCK: usize = 512;
    let p_old = &merged.p_old;
    let offset = merged.old_offset.as_deref();
    let mut row = Vec::new();
    for i in 0..store.shards() {
        let mut reader = store.u_shard_reader(i)?;
        let mut writer = dst.open_writer(i, k_new)?;
        let mut count = 0usize;
        let mut buf: Vec<Vec<f64>> = Vec::with_capacity(ROTATE_BLOCK);
        loop {
            buf.clear();
            while buf.len() < ROTATE_BLOCK {
                if !reader.next_row(&mut row)? {
                    break;
                }
                if row.len() != p_old.rows() {
                    return Err(Error::shape(format!(
                        "update: parent U shard {i} row has {} cols, expected {}",
                        row.len(),
                        p_old.rows()
                    )));
                }
                buf.push(row.clone());
            }
            if buf.is_empty() {
                break;
            }
            let slab = Matrix::from_rows(&buf)?;
            let mut rotated = matmul(&slab, p_old)?;
            if let Some(off) = offset {
                for rix in 0..rotated.rows() {
                    for (v, o) in rotated.row_mut(rix).iter_mut().zip(off.iter()) {
                        *v += o;
                    }
                }
            }
            for rix in 0..rotated.rows() {
                let urow = rotated.row(rix);
                writer.write_row(urow)?;
                norms.write_row(&[embedding_norm(urow, &merged.sigma)])?;
            }
            count += rotated.rows();
            if buf.len() < ROTATE_BLOCK {
                break;
            }
        }
        writer.finish()?;
        shard_rows.push(count);
        total += count;
    }

    // New rows: the pass-3 output shards, renumbered after the old ones.
    let src = ShardSet::new(work_dir, "U", InputFormat::Bin)?;
    for i in 0..new_shards {
        let mut reader = src.open_reader(i)?;
        let mut writer = dst.open_writer(store.shards() + i, k_new)?;
        let mut count = 0usize;
        while reader.next_row(&mut row)? {
            if row.len() != k_new {
                return Err(Error::shape(format!(
                    "update: rotated shard {i} row has {} cols, expected {k_new}",
                    row.len()
                )));
            }
            writer.write_row(&row)?;
            norms.write_row(&[embedding_norm(&row, &merged.sigma)])?;
            count += 1;
        }
        writer.finish()?;
        shard_rows.push(count);
        total += count;
    }
    norms.finish()?;

    crate::serve::store::model_manifest(
        total,
        store.n(),
        k_new,
        &shard_rows,
        means_new.is_some(),
        generation,
        Some(store.generation()),
        Some(seed),
    )
    .save(gen_dir.join("model.manifest"))?;
    Ok(total)
}
