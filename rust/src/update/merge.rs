//! Leader-side merge-and-truncate of an existing rank-k model with a new
//! row batch — the small-matrix half of the incremental update.
//!
//! Setting: the live generation factors `A₀ ≈ U₀ Σ₀ Vᵀ` (U₀, V
//! orthonormal) and a new batch `A₁` (`m₁ x n`). The streaming passes over
//! `A₁` (see [`crate::update::builder`]) deliver only small matrices:
//!
//! * `G = Yᵀ Y` where `Y = A₁ [V | Ω⊥]` — the fused project+gram pass with
//!   a composite operand: the first k columns of `Y` are `B = A₁ V` (the
//!   batch in the old latent basis), the last r are a Gaussian sketch of
//!   the *residual* `H = A₁ (I - V Vᵀ)` (Halko's range finder applied
//!   block-wise, which is exactly what makes the update composable).
//! * `W_h = A₁ᵀ U_h` where `U_h = Y_r M_r` orthonormalizes the residual
//!   sketch — the standard U-recovery pass with a block-diagonal operand.
//!
//! From those this module builds the Zha–Simon middle matrix over the
//! orthonormal bases `[U₀ | I]` (rows) and `[V | Q]` (columns),
//!
//! ```text
//! N = [ diag(Σ₀)      0   ]          [A₀]        [U₀  0] [          ]
//!     [ B         U_h·T·Q ]   s.t.   [A₁]  =     [0   I] [    N     ] [V | Q]ᵀ
//! ```
//!
//! eigensolves the `(k+r)x(k+r)` Gram `NᵀN = G_m Θ² G_mᵀ` (never touching
//! `m` anywhere), and returns the three small rotations the driver needs:
//! the new `Σ`, the new `V = [V|Q] G_m Θ`, a `k x k'` rotation `P_old` for
//! the existing U shards, and a `(k+r) x k'` rotation `P_new` for the new
//! rows' `[B | U_h]` shards.
//!
//! Centered (PCA) models add one wrinkle: re-centering the old block about
//! the merged mean is the rank-one shift `A₀ - 1 μ'ᵀ = U₀Σ₀Vᵀ + 1 c₀ᵀ`
//! with `c₀ = μ₀ - μ'`. Because `1ᵀ(A₀ - 1μ₀ᵀ) = 0` forces `1 ⊥ U₀`, the
//! normalized ones-vector extends the left basis orthonormally, and the
//! shift becomes one extra "virtual row" `√m₀ c₀ᵀ` of `N` — its `NᵀN`
//! contribution is the rank-one term `m₀ ĉ ĉᵀ`, and its share of the new
//! `U` surfaces as a constant per-row offset on the rotated old shards.

use crate::backend::BackendRef;
use crate::error::{Error, Result};
use crate::linalg::{matmul, matmul_tn, thin_qr, Matrix};
use crate::svd::pipeline::guarded_inverse;

/// Relative cutoff for `Θ⁻¹` when forming the rotations — numerically-zero
/// directions only (matches the pipeline's completion cutoff).
const THETA_CUTOFF_REL: f64 = 1e-12;

/// The small matrices the streaming passes delivered to the leader.
pub struct MergeInput<'a> {
    /// Singular values of the live generation (length k).
    pub sigma0: &'a [f64],
    /// Right singular vectors of the live generation, `n x k`.
    pub v: &'a Matrix,
    /// `(k+r) x (k+r)` Gram of `Y = A₁ [V | Ω⊥]` from pass 1.
    pub gram: &'a Matrix,
    /// `n x r` completion `A₁ᵀ U_h` (columns k.. of the pass-2 partial).
    pub w_h: &'a Matrix,
    /// `r x r` residual orthonormalizer `M_r = V_y Σ_y⁻¹` (guarded).
    pub m_r: &'a Matrix,
    /// Row count of the old model (the centered virtual row's weight).
    pub m0: usize,
    /// Mean shift `μ₀ - μ'` for centered models (None when uncentered).
    pub c0: Option<&'a [f64]>,
}

/// The rotations and factors of the next generation.
pub struct MergeOutput {
    /// New singular values, descending (length k').
    pub sigma: Vec<f64>,
    /// New right singular vectors, `n x k'`.
    pub v_new: Matrix,
    /// Rotation for old U shards: `u'ᵀ = uᵀ P_old (+ offset)`, `k x k'`.
    pub p_old: Matrix,
    /// Constant row offset for old shards (centered models only, length k').
    pub old_offset: Option<Vec<f64>>,
    /// Rotation for the new rows' `[B | U_h]` shards, `(k+r) x k'`.
    pub p_new: Matrix,
}

/// `a - b`, elementwise.
fn sub(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.shape() != b.shape() {
        return Err(Error::shape(format!("sub: {:?} vs {:?}", a.shape(), b.shape())));
    }
    let mut out = a.clone();
    for (o, x) in out.data_mut().iter_mut().zip(b.data()) {
        *o -= x;
    }
    Ok(out)
}

/// Copy `src` into `dst` at `(r0, c0)`.
fn set_block(dst: &mut Matrix, r0: usize, c0: usize, src: &Matrix) {
    for i in 0..src.rows() {
        for j in 0..src.cols() {
            dst.set(r0 + i, c0 + j, src.get(i, j));
        }
    }
}

/// The component of `x` (a column per entry of `cols`) orthogonal to the
/// columns of `v`: `x - v (vᵀ x)`.
fn project_out(v: &Matrix, x: &Matrix) -> Result<Matrix> {
    if x.cols() == 0 {
        return Ok(x.clone());
    }
    let vt_x = matmul_tn(v, x)?;
    sub(x, &matmul(v, &vt_x)?)
}

/// Merge the old factors with the batch's streamed partials and truncate to
/// `k_new` (capped at the merged basis width). See the module docs for the
/// construction; all dense work here is O((k+r)³) plus O(n·(k+r)²) for the
/// basis assembly — nothing scales with m.
pub fn merge_truncate(
    inp: &MergeInput,
    k_new: usize,
    backend: &BackendRef,
) -> Result<MergeOutput> {
    let k = inp.sigma0.len();
    let r = inp.m_r.cols();
    let n = inp.v.rows();
    if inp.v.cols() != k {
        return Err(Error::shape(format!(
            "merge: V is {:?}, sigma0 has {k} values",
            inp.v.shape()
        )));
    }
    if inp.gram.shape() != (k + r, k + r) {
        return Err(Error::shape(format!(
            "merge: gram is {:?}, expected ({}, {})",
            inp.gram.shape(),
            k + r,
            k + r
        )));
    }
    if inp.w_h.shape() != (n, r) {
        return Err(Error::shape(format!(
            "merge: w_h is {:?}, expected ({n}, {r})",
            inp.w_h.shape()
        )));
    }

    // Residual directions in row space: T̃ = (I - VVᵀ) W_h, one column per
    // sketch direction; centered models append the mean-shift component
    // c0⊥ = (I - VVᵀ) c0 so the virtual row's residual is representable.
    let tt = project_out(inp.v, inp.w_h)?;
    let qr_cols = match inp.c0 {
        Some(c0) => {
            if c0.len() != n {
                return Err(Error::shape(format!(
                    "merge: c0 has {} entries, expected n={n}",
                    c0.len()
                )));
            }
            let c = Matrix::from_vec(n, 1, c0.to_vec())?;
            let c_perp = project_out(inp.v, &c)?;
            let mut m = Matrix::zeros(n, r + 1);
            set_block(&mut m, 0, 0, &tt);
            set_block(&mut m, 0, r, &c_perp);
            m
        }
        None => tt,
    };
    // Thin QR: Q (n x q) orthonormal and ⊥ V by construction of its input;
    // R's first r columns are the residual coords S ᵀ, its last column (if
    // centered) the virtual row's Q-coordinates.
    let q = qr_cols.cols();
    let (q_mat, rq) = if q > 0 {
        thin_qr(&qr_cols)?
    } else {
        (Matrix::zeros(n, 0), Matrix::zeros(0, 0))
    };
    // S (r x q): U_h-residual coords such that H ≈ U_h S Qᵀ.
    let s_mat = rq.slice_cols(0, r).t();

    // Gram blocks of Y = [B | Y_r]:  BᵀB, BᵀY_r, Y_rᵀY_r.
    let g_bb = slice_block(inp.gram, 0, 0, k, k);
    let g_br = slice_block(inp.gram, 0, k, k, r);
    let g_rr = slice_block(inp.gram, k, k, r, r);
    // BᵀU_h = (BᵀY_r) M_r and U_hᵀU_h = M_rᵀ (Y_rᵀY_r) M_r — U_h is only
    // *approximately* orthonormal when the residual is rank-deficient (the
    // guarded inverse zeroes dead directions), so keep the exact Gram.
    let b_uh = matmul(&g_br, inp.m_r)?; // k x r
    let uh_uh = matmul_tn(inp.m_r, &matmul(&g_rr, inp.m_r)?)?; // r x r

    // NᵀN over the merged basis [V | Q]:
    //   [ diag(Σ₀²) + BᵀB      BᵀU_h Sᵀ... ]
    //   [ ...                  S U_hᵀU_h Sᵀ ]  (+ m₀ ĉĉᵀ when centered)
    let d = k + q;
    let mut nn = Matrix::zeros(d, d);
    let mut top_left = g_bb;
    for i in 0..k {
        top_left.set(i, i, top_left.get(i, i) + inp.sigma0[i] * inp.sigma0[i]);
    }
    set_block(&mut nn, 0, 0, &top_left);
    if q > 0 {
        let top_right = matmul(&b_uh, &s_mat)?; // (k x r)(r x q) = k x q
        set_block(&mut nn, 0, k, &top_right);
        set_block(&mut nn, k, 0, &top_right.t());
        let bottom = matmul(&s_mat.t(), &matmul(&uh_uh, &s_mat)?)?; // q x q
        set_block(&mut nn, k, k, &bottom);
    }
    let c_hat = inp.c0.map(|c0| {
        // ĉ = coords of c₀ in [V | Q]: [Vᵀc₀ ; R's last column].
        let mut c_vec = vec![0.0; d];
        for j in 0..k {
            c_vec[j] = (0..n).map(|i| inp.v.get(i, j) * c0[i]).sum();
        }
        for j in 0..q {
            c_vec[k + j] = rq.get(j, r);
        }
        c_vec
    });
    if let Some(c_hat) = &c_hat {
        let w = inp.m0 as f64;
        for i in 0..d {
            for j in 0..d {
                nn.set(i, j, nn.get(i, j) + w * c_hat[i] * c_hat[j]);
            }
        }
    }

    // The small eigensolve: NᵀN = G_m Θ² G_mᵀ, descending.
    let (theta2, g_m) = backend.eigh(&nn)?;
    let k_new = k_new.min(d).max(1);
    let sigma: Vec<f64> = theta2[..k_new].iter().map(|&w| w.max(0.0).sqrt()).collect();
    let inv_theta = guarded_inverse(&sigma, THETA_CUTOFF_REL);
    let g_k = g_m.slice_cols(0, k_new); // d x k'

    // V' = [V | Q] G_m[:, :k'].
    let mut v_new = matmul(inp.v, &g_k.slice_rows(0, k))?;
    if q > 0 {
        v_new.add_assign(&matmul(&q_mat, &g_k.slice_rows(k, d))?)?;
    }

    // Old-shard rotation: U₀'s F-block is diag(Σ₀) G_m Θ⁻¹.
    let mut p_old = g_k.slice_rows(0, k);
    for i in 0..k {
        for j in 0..k_new {
            p_old.set(i, j, inp.sigma0[i] * p_old.get(i, j) * inv_theta[j]);
        }
    }
    // Centered: the virtual row's F-row spreads 1/√m₀ onto every old row —
    // a constant offset ĉᵀ G_m Θ⁻¹ after the √m₀ weights cancel.
    let old_offset = c_hat.map(|c_hat| {
        (0..k_new)
            .map(|j| (0..d).map(|i| c_hat[i] * g_k.get(i, j)).sum::<f64>() * inv_theta[j])
            .collect()
    });

    // New-shard rotation over the [B | U_h] shards:
    //   rows 0..k  -> G_m's V-block, rows k.. -> S · G_m's Q-block.
    let mut p_new = Matrix::zeros(k + r, k_new);
    set_block(&mut p_new, 0, 0, &g_k.slice_rows(0, k));
    if q > 0 {
        set_block(&mut p_new, k, 0, &matmul(&s_mat, &g_k.slice_rows(k, d))?);
    }
    for i in 0..k + r {
        for j in 0..k_new {
            p_new.set(i, j, p_new.get(i, j) * inv_theta[j]);
        }
    }

    Ok(MergeOutput { sigma, v_new, p_old, old_offset, p_new })
}

/// `src[r0.., c0..]` of shape `(rows, cols)` as a new matrix.
fn slice_block(src: &Matrix, r0: usize, c0: usize, rows: usize, cols: usize) -> Matrix {
    Matrix::from_fn(rows, cols, |i, j| src.get(r0 + i, c0 + j))
}

/// One already-factored row block: `A ≈ U Σ Vᵀ (+ 1 μᵀ)` with `U`
/// orthonormal over this block's rows. The `U` itself never enters the
/// merge — only its row count and the small factors.
pub struct FactoredBlock<'a> {
    /// Singular values, descending (length k).
    pub sigma: &'a [f64],
    /// Right singular vectors, `n x k`.
    pub v: &'a Matrix,
    /// Rows in the block.
    pub m: usize,
    /// Column means for centered (PCA) factorizations — the factors then
    /// describe `A - 1 μᵀ`. Both blocks must agree on centeredness.
    pub mu: Option<&'a [f64]>,
}

/// The rotations and factors of a [`merge_factored`] merge.
pub struct FactoredMergeOutput {
    /// New singular values, descending (length k').
    pub sigma: Vec<f64>,
    /// New right singular vectors, `n x k'`.
    pub v_new: Matrix,
    /// Rotation for the first block's U shards, `k₀ x k'`.
    pub p_old: Matrix,
    /// Constant row offset for the first block (centered only, length k').
    pub old_offset: Option<Vec<f64>>,
    /// Rotation for the second block's U shards, `k₁ x k'`.
    pub p_new: Matrix,
    /// Constant row offset for the second block (centered only, length k').
    pub new_offset: Option<Vec<f64>>,
    /// Merged column means (centered only).
    pub means: Option<Vec<f64>>,
}

/// Merge two *already factored* row blocks — the streaming route's variant
/// of [`merge_truncate`], where the new rows arrive as a finished one-pass
/// factorization ([`crate::stream`]) rather than as raw rows to sketch.
///
/// With `Ã_b = A_b - 1 μ'ᵀ = U_b Σ_b V_bᵀ + 1 c_bᵀ` (`c_b = μ_b - μ'`, the
/// re-centering about the merged mean `μ'`), the concatenation factors as
/// `B Z̃ᵀ` over the orthonormal left basis
/// `B = [U₀ | 0 | 1/√m₀ | 0 ; 0 | U₁ | 0 | 1/√m₁]` with
/// `Z̃ = [V₀Σ₀ | V₁Σ₁ | √m₀ c₀ | √m₁ c₁]` — orthonormal because a centered
/// block's `1ᵀU = 0` exactly, and the blocks live on disjoint rows.
/// Eigensolving the `(k₀+k₁+2)²` Gram `Z̃ᵀZ̃ = Q Θ² Qᵀ` gives
/// `Σ' = Θ`, `V' = Z̃ Q Θ⁻¹`, and `U' = B Q` — so each block's shards
/// rotate by their slice of `Q` plus a constant `Q`-row/√m offset, and
/// nothing anywhere scales with `m`.
pub fn merge_factored(
    old: &FactoredBlock,
    new: &FactoredBlock,
    k_new: usize,
    backend: &BackendRef,
) -> Result<FactoredMergeOutput> {
    let (k0, k1) = (old.sigma.len(), new.sigma.len());
    let n = old.v.rows();
    if old.v.cols() != k0 || new.v.cols() != k1 {
        return Err(Error::shape(format!(
            "merge_factored: V shapes {:?}/{:?} vs sigma lengths {k0}/{k1}",
            old.v.shape(),
            new.v.shape()
        )));
    }
    if new.v.rows() != n {
        return Err(Error::shape(format!(
            "merge_factored: blocks disagree on n ({n} vs {})",
            new.v.rows()
        )));
    }
    if old.mu.is_some() != new.mu.is_some() {
        return Err(Error::Config(
            "merge_factored: one block is centered and the other is not — \
             a PCA model can only absorb a centered stream (and vice versa)"
                .into(),
        ));
    }
    if old.m == 0 || new.m == 0 {
        return Err(Error::Config("merge_factored: both blocks need rows".into()));
    }
    let centered = old.mu.is_some();
    let (w0, w1) = (old.m as f64, new.m as f64);

    // Merged mean and the per-block re-centering shifts.
    let means = old.mu.zip(new.mu).map(|(mu0, mu1)| {
        (0..n)
            .map(|j| (w0 * mu0[j] + w1 * mu1[j]) / (w0 + w1))
            .collect::<Vec<f64>>()
    });
    let d = k0 + k1 + if centered { 2 } else { 0 };
    let z = Matrix::from_fn(n, d, |i, j| {
        if j < k0 {
            old.v.get(i, j) * old.sigma[j]
        } else if j < k0 + k1 {
            new.v.get(i, j - k0) * new.sigma[j - k0]
        } else {
            let mu = means.as_ref().expect("centered");
            if j == k0 + k1 {
                w0.sqrt() * (old.mu.expect("centered")[i] - mu[i])
            } else {
                w1.sqrt() * (new.mu.expect("centered")[i] - mu[i])
            }
        }
    });

    let gram = matmul_tn(&z, &z)?;
    let (theta2, q) = backend.eigh(&gram)?;
    let k_new = k_new.min(d).max(1);
    let sigma: Vec<f64> = theta2[..k_new].iter().map(|&w| w.max(0.0).sqrt()).collect();
    let inv_theta = guarded_inverse(&sigma, THETA_CUTOFF_REL);
    let q_k = q.slice_cols(0, k_new);
    let v_new = matmul(&z, &q_k)?.scale_cols(&inv_theta)?;
    let p_old = q_k.slice_rows(0, k0);
    let p_new = q_k.slice_rows(k0, k0 + k1);
    let (old_offset, new_offset) = if centered {
        (
            Some((0..k_new).map(|j| q_k.get(k0 + k1, j) / w0.sqrt()).collect()),
            Some((0..k_new).map(|j| q_k.get(k0 + k1 + 1, j) / w1.sqrt()).collect()),
        )
    } else {
        (None, None)
    };
    Ok(FactoredMergeOutput { sigma, v_new, p_old, old_offset, p_new, new_offset, means })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::linalg::exact_svd;
    use crate::rng::Gaussian;
    use std::sync::Arc;

    fn rand(rows: usize, cols: usize, seed: u64) -> Matrix {
        let g = Gaussian::new(seed);
        Matrix::from_fn(rows, cols, |i, j| g.sample(i as u64, j as u64))
    }

    /// Dense oracle for the whole merge: factor A0 exactly at rank k,
    /// compute the pass outputs densely, merge, and check the updated
    /// factors reconstruct [A0; A1].
    fn run_dense_merge(centered: bool) {
        let (m0, m1, n, k, r) = (40usize, 14usize, 10usize, 3usize, 5usize);
        let backend: BackendRef = Arc::new(NativeBackend::new());

        // Rank-k A0 so its truncated SVD is exact, and a low-rank batch so
        // the r-wide residual sketch captures its range exactly (the
        // general lossy case is exercised by the integration tests).
        let raw0 = matmul(&rand(m0, k, 1), &rand(k, n, 2)).unwrap();
        let a1_raw = matmul(&rand(m1, 3, 3), &rand(3, n, 4)).unwrap();

        // Means of the concatenation (centered mode) — the update's passes
        // see A1 - 1 μ'ᵀ and the old factors describe A0 - 1 μ₀ᵀ.
        let (a0, a1, c0) = if centered {
            let mu0: Vec<f64> = (0..n).map(|j| raw0.col(j).iter().sum::<f64>() / m0 as f64).collect();
            let mu1: Vec<f64> =
                (0..n).map(|j| a1_raw.col(j).iter().sum::<f64>() / m1 as f64).collect();
            let m = (m0 + m1) as f64;
            let mu_new: Vec<f64> = (0..n)
                .map(|j| (m0 as f64 * mu0[j] + m1 as f64 * mu1[j]) / m)
                .collect();
            let a0c = Matrix::from_fn(m0, n, |i, j| raw0.get(i, j) - mu0[j]);
            let a1c = Matrix::from_fn(m1, n, |i, j| a1_raw.get(i, j) - mu_new[j]);
            let c0: Vec<f64> = (0..n).map(|j| mu0[j] - mu_new[j]).collect();
            (a0c, a1c, Some(c0))
        } else {
            (raw0.clone(), a1_raw.clone(), None)
        };

        // Old factors (rank k exact for uncentered; centering a rank-k
        // matrix is rank k+1, so keep k big enough — here rank(a0) <= k+1
        // means we need the centered case to still be exact: centering
        // A0 = L R about its own means keeps rank <= k, since the mean row
        // is in the row space... not in general. Use k+1 for safety.
        let k_eff = if centered { k + 1 } else { k };
        let svd0 = exact_svd(&a0).unwrap();
        let sigma0: Vec<f64> = svd0.sigma[..k_eff].to_vec();
        let u0 = svd0.u.slice_cols(0, k_eff);
        let v0 = svd0.v.slice_cols(0, k_eff);

        // Pass 1: Y = A1 [V | (I - VVᵀ)Ω], G = YᵀY.
        let omega = rand(n, r, 7);
        let om_perp = project_out(&v0, &omega).unwrap();
        let mut omega_c = Matrix::zeros(n, k_eff + r);
        set_block(&mut omega_c, 0, 0, &v0);
        set_block(&mut omega_c, 0, k_eff, &om_perp);
        let y = matmul(&a1, &omega_c).unwrap();
        let g = matmul_tn(&y, &y).unwrap();

        // Leader: M_r from the residual gram.
        let g_rr = slice_block(&g, k_eff, k_eff, r, r);
        let (w_eig, v_y) = backend.eigh(&g_rr).unwrap();
        let sig_y: Vec<f64> = w_eig.iter().map(|&w| w.max(0.0).sqrt()).collect();
        let inv_y = guarded_inverse(&sig_y, 1e-10);
        let m_r = v_y.scale_cols(&inv_y).unwrap();

        // Pass 2: U0-shards = [B | U_h], W = A1ᵀ [B | U_h].
        let mut m2 = Matrix::zeros(k_eff + r, k_eff + r);
        set_block(&mut m2, 0, 0, &Matrix::eye(k_eff));
        set_block(&mut m2, k_eff, k_eff, &m_r);
        let b_uh = matmul(&y, &m2).unwrap(); // m1 x (k+r)
        let w = matmul_tn(&a1, &b_uh).unwrap();
        let w_h = w.slice_cols(k_eff, k_eff + r);

        let out = merge_truncate(
            &MergeInput {
                sigma0: &sigma0,
                v: &v0,
                gram: &g,
                w_h: &w_h,
                m_r: &m_r,
                m0,
                c0: c0.as_deref(),
            },
            k_eff + r.min(m1),
            &backend,
        )
        .unwrap();

        // Rebuild U from the two rotations and check the factorization.
        let mut u_old = matmul(&u0, &out.p_old).unwrap();
        if let Some(off) = &out.old_offset {
            for i in 0..u_old.rows() {
                for (j, o) in off.iter().enumerate() {
                    u_old.set(i, j, u_old.get(i, j) + o);
                }
            }
        }
        let u_new_rows = matmul(&b_uh, &out.p_new).unwrap();
        let u = u_old.vstack(&u_new_rows).unwrap();
        let recon = matmul(&u.scale_cols(&out.sigma).unwrap(), &out.v_new.t()).unwrap();
        // The merged factorization targets the concatenation centered about
        // the *merged* mean: the old block shifts by 1 c₀ᵀ.
        let a0_shifted = match &c0 {
            Some(c0) => Matrix::from_fn(m0, n, |i, j| a0.get(i, j) + c0[j]),
            None => a0.clone(),
        };
        let want = a0_shifted.vstack(&a1).unwrap();
        let rel = recon.max_abs_diff(&want) / want.max_abs();
        assert!(rel < 1e-8, "centered={centered}: reconstruction rel err {rel}");

        // Orthonormality of the produced factors (up to dead directions).
        let utu = matmul_tn(&u, &u).unwrap();
        let vtv = matmul_tn(&out.v_new, &out.v_new).unwrap();
        let live = out.sigma.iter().filter(|&&s| s > 1e-9 * out.sigma[0]).count();
        for i in 0..live {
            for j in 0..live {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu.get(i, j) - want).abs() < 1e-8, "UᵀU[{i},{j}]");
                assert!((vtv.get(i, j) - want).abs() < 1e-8, "VᵀV[{i},{j}]");
            }
        }

        // Σ matches the dense SVD of the concatenation.
        let dense = exact_svd(&want).unwrap();
        for i in 0..live {
            let rel = (out.sigma[i] - dense.sigma[i]).abs() / dense.sigma[i].max(1e-12);
            assert!(rel < 1e-8, "sigma[{i}]: {} vs {}", out.sigma[i], dense.sigma[i]);
        }
    }

    #[test]
    fn dense_merge_reconstructs_concatenation() {
        run_dense_merge(false);
    }

    #[test]
    fn dense_merge_handles_centered_mean_shift() {
        run_dense_merge(true);
    }

    /// Oracle for merging two finished factorizations: exactly factor two
    /// low-rank blocks, merge, and check the rotations rebuild the SVD of
    /// the concatenation.
    fn run_factored_merge(centered: bool) {
        let (m0, m1, n) = (36usize, 20usize, 9usize);
        let backend: BackendRef = Arc::new(NativeBackend::new());
        let raw0 = matmul(&rand(m0, 3, 21), &rand(3, n, 22)).unwrap();
        let raw1 = matmul(&rand(m1, 4, 23), &rand(4, n, 24)).unwrap();

        let mean_of = |a: &Matrix| -> Vec<f64> {
            (0..n).map(|j| a.col(j).iter().sum::<f64>() / a.rows() as f64).collect()
        };
        let (a0, a1, mu0, mu1) = if centered {
            let mu0 = mean_of(&raw0);
            let mu1 = mean_of(&raw1);
            (
                Matrix::from_fn(m0, n, |i, j| raw0.get(i, j) - mu0[j]),
                Matrix::from_fn(m1, n, |i, j| raw1.get(i, j) - mu1[j]),
                Some(mu0),
                Some(mu1),
            )
        } else {
            (raw0.clone(), raw1.clone(), None, None)
        };

        // Exact factors of each block; keep every numerically-live direction
        // so the merge's input is lossless and the oracle check is tight.
        let keep = |s: &[f64]| s.iter().filter(|&&x| x > 1e-9 * s[0]).count();
        let svd0 = exact_svd(&a0).unwrap();
        let k0 = keep(&svd0.sigma);
        let svd1 = exact_svd(&a1).unwrap();
        let k1 = keep(&svd1.sigma);

        let out = merge_factored(
            &FactoredBlock {
                sigma: &svd0.sigma[..k0],
                v: &svd0.v.slice_cols(0, k0),
                m: m0,
                mu: mu0.as_deref(),
            },
            &FactoredBlock {
                sigma: &svd1.sigma[..k1],
                v: &svd1.v.slice_cols(0, k1),
                m: m1,
                mu: mu1.as_deref(),
            },
            k0 + k1 + 2,
            &backend,
        )
        .unwrap();

        // Rebuild U from the per-block rotations + offsets.
        let apply = |u: &Matrix, p: &Matrix, off: Option<&Vec<f64>>| {
            let mut r = matmul(u, p).unwrap();
            if let Some(off) = off {
                for i in 0..r.rows() {
                    for (j, o) in off.iter().enumerate() {
                        r.set(i, j, r.get(i, j) + o);
                    }
                }
            }
            r
        };
        let u0 = svd0.u.slice_cols(0, k0);
        let u1 = svd1.u.slice_cols(0, k1);
        let u = apply(&u0, &out.p_old, out.old_offset.as_ref())
            .vstack(&apply(&u1, &out.p_new, out.new_offset.as_ref()))
            .unwrap();
        let recon = matmul(&u.scale_cols(&out.sigma).unwrap(), &out.v_new.t()).unwrap();

        // Target: the concatenation centered about the *merged* mean.
        let want = match &out.means {
            Some(mu) => {
                let top = Matrix::from_fn(m0, n, |i, j| raw0.get(i, j) - mu[j]);
                let bot = Matrix::from_fn(m1, n, |i, j| raw1.get(i, j) - mu[j]);
                top.vstack(&bot).unwrap()
            }
            None => raw0.vstack(&raw1).unwrap(),
        };
        let rel = recon.max_abs_diff(&want) / want.max_abs();
        assert!(rel < 1e-8, "centered={centered}: factored merge rel err {rel}");

        // Σ matches the dense SVD of the concatenation on live directions.
        let dense = exact_svd(&want).unwrap();
        let live = out.sigma.iter().filter(|&&s| s > 1e-9 * out.sigma[0]).count();
        for i in 0..live {
            let rel = (out.sigma[i] - dense.sigma[i]).abs() / dense.sigma[i].max(1e-12);
            assert!(rel < 1e-8, "sigma[{i}]: {} vs {}", out.sigma[i], dense.sigma[i]);
        }
        // U orthonormal on live directions.
        let utu = matmul_tn(&u, &u).unwrap();
        for i in 0..live {
            for j in 0..live {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu.get(i, j) - want).abs() < 1e-8, "UᵀU[{i},{j}]");
            }
        }
    }

    #[test]
    fn factored_merge_reconstructs_concatenation() {
        run_factored_merge(false);
    }

    #[test]
    fn factored_merge_handles_centered_mean_shift() {
        run_factored_merge(true);
    }

    #[test]
    fn factored_merge_rejects_mixed_centering() {
        let backend: BackendRef = Arc::new(NativeBackend::new());
        let v = rand(6, 2, 31);
        let mu = vec![0.0; 6];
        let a = FactoredBlock { sigma: &[2.0, 1.0], v: &v, m: 10, mu: Some(&mu) };
        let b = FactoredBlock { sigma: &[1.5, 0.5], v: &v, m: 8, mu: None };
        assert!(merge_factored(&a, &b, 2, &backend).is_err());
    }

    #[test]
    fn merge_rejects_mismatched_shapes() {
        let backend: BackendRef = Arc::new(NativeBackend::new());
        let v = rand(8, 3, 1);
        let bad = MergeInput {
            sigma0: &[1.0, 0.5], // k=2 but V has 3 columns
            v: &v,
            gram: &Matrix::zeros(5, 5),
            w_h: &Matrix::zeros(8, 2),
            m_r: &Matrix::zeros(2, 2),
            m0: 10,
            c0: None,
        };
        assert!(merge_truncate(&bad, 2, &backend).is_err());
    }

    #[test]
    fn zero_residual_reduces_to_rotation() {
        // New rows entirely inside span(V): the residual machinery must
        // collapse gracefully (S ≈ 0) and Σ must still be exact.
        let backend: BackendRef = Arc::new(NativeBackend::new());
        let (m0, m1, n, k, r) = (30usize, 8usize, 6usize, 2usize, 3usize);
        let base = matmul(&rand(m0, k, 11), &rand(k, n, 12)).unwrap();
        let svd0 = exact_svd(&base).unwrap();
        let sigma0: Vec<f64> = svd0.sigma[..k].to_vec();
        let u0 = svd0.u.slice_cols(0, k);
        let v0 = svd0.v.slice_cols(0, k);
        // a1 rows are combinations of V columns => zero residual.
        let a1 = matmul(&rand(m1, k, 13), &v0.t()).unwrap();

        let omega = rand(n, r, 14);
        let om_perp = project_out(&v0, &omega).unwrap();
        let mut omega_c = Matrix::zeros(n, k + r);
        set_block(&mut omega_c, 0, 0, &v0);
        set_block(&mut omega_c, 0, k, &om_perp);
        let y = matmul(&a1, &omega_c).unwrap();
        let g = matmul_tn(&y, &y).unwrap();
        let g_rr = slice_block(&g, k, k, r, r);
        let (w_eig, v_y) = backend.eigh(&g_rr).unwrap();
        let sig_y: Vec<f64> = w_eig.iter().map(|&w| w.max(0.0).sqrt()).collect();
        let m_r = v_y.scale_cols(&guarded_inverse(&sig_y, 1e-7)).unwrap();
        let mut m2 = Matrix::zeros(k + r, k + r);
        set_block(&mut m2, 0, 0, &Matrix::eye(k));
        set_block(&mut m2, k, k, &m_r);
        let b_uh = matmul(&y, &m2).unwrap();
        let w_h = matmul_tn(&a1, &b_uh).unwrap().slice_cols(k, k + r);

        let out = merge_truncate(
            &MergeInput {
                sigma0: &sigma0,
                v: &v0,
                gram: &g,
                w_h: &w_h,
                m_r: &m_r,
                m0,
                c0: None,
            },
            k,
            &backend,
        )
        .unwrap();
        let mut u = matmul(&u0, &out.p_old).unwrap();
        u = u.vstack(&matmul(&b_uh, &out.p_new).unwrap()).unwrap();
        let recon = matmul(&u.scale_cols(&out.sigma).unwrap(), &out.v_new.t()).unwrap();
        let want = base.vstack(&a1).unwrap();
        assert!(recon.max_abs_diff(&want) / want.max_abs() < 1e-8);
    }
}
