//! Matrix ⇄ `xla::Literal` conversion (the f32 FFI boundary).

#[cfg(feature = "xla")]
use crate::error::{Error, Result};
use crate::linalg::Matrix;

/// Convert a matrix to an `f32` literal of shape `[rows, cols]`, zero-padding
/// rows up to `pad_rows` (the artifact's fixed block size).
#[cfg(feature = "xla")]
pub fn matrix_to_literal_f32(m: &Matrix, pad_rows: usize) -> Result<xla::Literal> {
    let (rows, cols) = m.shape();
    if pad_rows < rows {
        return Err(Error::shape(format!(
            "pad_rows {pad_rows} < matrix rows {rows}"
        )));
    }
    let mut data = vec![0.0f32; pad_rows * cols];
    for (dst, src) in data.chunks_exact_mut(cols).zip((0..rows).map(|i| m.row(i))) {
        for (d, s) in dst.iter_mut().zip(src.iter()) {
            *d = *s as f32;
        }
    }
    let lit = xla::Literal::vec1(&data);
    Ok(lit.reshape(&[pad_rows as i64, cols as i64])?)
}

/// Convert a literal's `f32` payload back to a Matrix with the given shape,
/// keeping only the first `keep_rows` rows (drop the zero padding).
#[cfg(feature = "xla")]
pub fn literal_to_matrix_f32(lit: &xla::Literal, rows: usize, cols: usize, keep_rows: usize) -> Result<Matrix> {
    let data: Vec<f32> = lit.to_vec()?;
    if data.len() != rows * cols {
        return Err(Error::shape(format!(
            "literal has {} elements, expected {}x{}",
            data.len(),
            rows,
            cols
        )));
    }
    Matrix::from_f32(keep_rows.min(rows), cols, &data[..keep_rows.min(rows) * cols])
}

/// Flatten a matrix to f32 with row padding (service-thread message payload).
pub fn matrix_to_f32_padded(m: &Matrix, pad_rows: usize) -> Vec<f32> {
    let (rows, cols) = m.shape();
    debug_assert!(pad_rows >= rows);
    let mut data = vec![0.0f32; pad_rows * cols];
    for i in 0..rows {
        let src = m.row(i);
        let dst = &mut data[i * cols..(i + 1) * cols];
        for (d, s) in dst.iter_mut().zip(src.iter()) {
            *d = *s as f32;
        }
    }
    data
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_and_flatten() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let v = matrix_to_f32_padded(&m, 4);
        assert_eq!(v, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn literal_roundtrip() {
        let m = Matrix::from_rows(&[vec![1.5, -2.0, 0.25]]).unwrap();
        let lit = matrix_to_literal_f32(&m, 2).unwrap();
        let back = literal_to_matrix_f32(&lit, 2, 3, 1).unwrap();
        assert_eq!(back, m);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn pad_too_small_rejected() {
        let m = Matrix::zeros(4, 2);
        assert!(matrix_to_literal_f32(&m, 2).is_err());
    }
}
