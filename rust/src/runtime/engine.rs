//! The PJRT engine: compile-on-first-use cache over HLO-text artifacts.
//!
//! NOT thread-safe (`PjRtClient` is `Rc`-based); use through
//! [`crate::runtime::service::XlaService`] from multi-threaded code.

use crate::error::{Error, Result};
use crate::runtime::artifact::{ArtifactMeta, Manifest};
use crate::util::Logger;
use std::collections::HashMap;
use std::path::PathBuf;

static LOG: Logger = Logger::new("runtime");

/// Owns the PJRT client, the manifest, and the executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU PJRT client and load the artifact manifest from `dir`.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        LOG.info(&format!(
            "pjrt client up: platform={} artifacts={} dir={}",
            client.platform_name(),
            manifest.len(),
            dir.display()
        ));
        Ok(Engine { client, manifest, dir, executables: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) the named artifact.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let meta = self
                .manifest
                .by_name(name)
                .ok_or_else(|| Error::Artifact(format!("no artifact named `{name}`")))?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(&meta.path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let t0 = std::time::Instant::now();
            let exe = self.client.compile(&comp)?;
            LOG.debug(&format!(
                "compiled {name} in {:.1}ms",
                t0.elapsed().as_secs_f64() * 1e3
            ));
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Execute artifact `name` with f32 inputs shaped per `shapes`.
    /// Returns the flattened f32 payload of each output.
    pub fn execute_f32(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let meta = self
            .manifest
            .by_name(name)
            .ok_or_else(|| Error::Artifact(format!("no artifact named `{name}`")))?;
        if inputs.len() != meta.ins.len() {
            return Err(Error::shape(format!(
                "{name}: {} inputs given, expected {}",
                inputs.len(),
                meta.ins.len()
            )));
        }
        for (idx, ((data, shape), want)) in inputs.iter().zip(meta.ins.iter()).enumerate() {
            let numel: usize = shape.iter().product();
            if shape[..] != want[..] || data.len() != numel {
                return Err(Error::shape(format!(
                    "{name}: input {idx} is {shape:?} ({} elems), artifact wants {want:?}",
                    data.len()
                )));
            }
        }
        let n_outs = meta.outs.len();

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims).map_err(Error::from)
            })
            .collect::<Result<_>>()?;

        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple, even 1-ary.
        let parts = result.to_tuple()?;
        if parts.len() != n_outs {
            return Err(Error::shape(format!(
                "{name}: got {} outputs, manifest says {n_outs}",
                parts.len()
            )));
        }
        parts
            .iter()
            .map(|lit| lit.to_vec::<f32>().map_err(Error::from))
            .collect()
    }

    /// Look up artifact metadata for a program/shape (see [`Manifest::lookup`]).
    pub fn lookup(&self, program: &str, rows: usize, n: usize, k: usize) -> Option<ArtifactMeta> {
        self.manifest.lookup(program, rows, n, k).cloned()
    }

    pub fn artifacts_dir(&self) -> &PathBuf {
        &self.dir
    }
}
