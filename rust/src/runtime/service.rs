//! The XLA service thread: thread-safe access to the (single-threaded) PJRT
//! engine.
//!
//! `PjRtClient` is `Rc`-based, so the [`Engine`] cannot cross threads.
//! [`XlaService::start`] moves it onto a dedicated thread; workers hold a
//! cloneable [`XlaHandle`] and make synchronous call-response RPCs over
//! channels. Operationally this models the realistic deployment where all
//! Split-Process workers on a node share one accelerator; requests are
//! serialized in arrival order.

use crate::error::{Error, Result};
use crate::runtime::artifact::Manifest;
use crate::runtime::engine::Engine;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A request: execute `name` with flattened f32 inputs (+shapes), reply with
/// flattened f32 outputs.
struct ExecRequest {
    name: String,
    inputs: Vec<(Vec<f32>, Vec<usize>)>,
    reply: mpsc::SyncSender<Result<Vec<Vec<f32>>>>,
}

enum Message {
    Exec(ExecRequest),
    Shutdown,
}

/// Cloneable, `Send + Sync` handle to the XLA service thread.
#[derive(Clone)]
pub struct XlaHandle {
    tx: Arc<Mutex<mpsc::Sender<Message>>>,
    manifest: Arc<Manifest>,
    platform: String,
}

impl XlaHandle {
    /// Execute artifact `name`. Blocks until the service replies.
    pub fn execute(
        &self,
        name: &str,
        inputs: Vec<(Vec<f32>, Vec<usize>)>,
    ) -> Result<Vec<Vec<f32>>> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        {
            let tx = self.tx.lock().map_err(|_| Error::Other("xla service poisoned".into()))?;
            tx.send(Message::Exec(ExecRequest {
                name: name.to_string(),
                inputs,
                reply: reply_tx,
            }))
            .map_err(|_| Error::Other("xla service thread gone".into()))?;
        }
        reply_rx
            .recv()
            .map_err(|_| Error::Other("xla service dropped reply".into()))?
    }

    /// The artifact manifest (shape lookups happen caller-side, no RPC).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> &str {
        &self.platform
    }
}

/// Owns the service thread; dropping shuts it down.
pub struct XlaService {
    handle: XlaHandle,
    join: Option<JoinHandle<()>>,
    tx: mpsc::Sender<Message>,
}

impl XlaService {
    /// Boot an engine over `artifacts_dir` on a fresh service thread.
    pub fn start(artifacts_dir: &str) -> Result<Self> {
        // Build the engine on the service thread (PjRtClient must be born
        // there); ferry construction errors back through a channel.
        let (boot_tx, boot_rx) = mpsc::sync_channel::<Result<(Manifest, String)>>(1);
        let (tx, rx) = mpsc::channel::<Message>();
        let dir = artifacts_dir.to_string();
        let join = std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || {
                let mut engine = match Engine::new(&dir) {
                    Ok(e) => {
                        let _ = boot_tx.send(Ok((e.manifest().clone(), e.platform_name())));
                        e
                    }
                    Err(err) => {
                        let _ = boot_tx.send(Err(err));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Message::Shutdown => break,
                        Message::Exec(req) => {
                            let ins: Vec<(&[f32], &[usize])> = req
                                .inputs
                                .iter()
                                .map(|(d, s)| (d.as_slice(), s.as_slice()))
                                .collect();
                            let out = engine.execute_f32(&req.name, &ins);
                            let _ = req.reply.send(out);
                        }
                    }
                }
            })
            .map_err(|e| Error::Other(format!("cannot spawn xla service: {e}")))?;

        let (manifest, platform) = boot_rx
            .recv()
            .map_err(|_| Error::Other("xla service died during boot".into()))??;
        let handle = XlaHandle {
            tx: Arc::new(Mutex::new(tx.clone())),
            manifest: Arc::new(manifest),
            platform,
        };
        Ok(XlaService { handle, join: Some(join), tx })
    }

    pub fn handle(&self) -> XlaHandle {
        self.handle.clone()
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        let _ = self.tx.send(Message::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
