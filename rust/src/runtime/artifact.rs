//! Artifact manifest parsing and shape lookup.
//!
//! `artifacts/manifest.txt` is emitted by `python/compile/aot.py`: one
//! `key=value`-tokenized line per artifact, e.g.
//!
//! ```text
//! program=fused name=fused_b256_n256_k32 file=fused_b256_n256_k32.hlo.txt \
//!     dtype=float32 block=256 n=256 k=32 ins=256x256,256x32 outs=256x32,32x32
//! ```

use crate::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub program: String,
    pub name: String,
    /// Absolute path to the HLO text file.
    pub path: PathBuf,
    pub dtype: String,
    pub block: usize,
    pub n: usize,
    pub k: usize,
    /// Input shapes, row-major dims.
    pub ins: Vec<Vec<usize>>,
    /// Output shapes.
    pub outs: Vec<Vec<usize>>,
}

/// Parsed manifest with shape-based lookup.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    by_program: HashMap<String, Vec<ArtifactMeta>>,
    count: usize,
}

fn parse_shapes(s: &str) -> Result<Vec<Vec<usize>>> {
    if s.is_empty() {
        return Ok(vec![]);
    }
    s.split(',')
        .map(|shape| {
            shape
                .split('x')
                .map(|d| {
                    d.parse::<usize>()
                        .map_err(|_| Error::parse(format!("bad shape dim `{d}`")))
                })
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; artifact files are resolved relative to `dir`.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut m = Manifest::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut kv: HashMap<&str, &str> = HashMap::new();
            for tok in line.split_whitespace() {
                let (k, v) = tok.split_once('=').ok_or_else(|| {
                    Error::parse(format!("manifest line {}: bad token `{tok}`", lineno + 1))
                })?;
                kv.insert(k, v);
            }
            let get = |k: &str| -> Result<&str> {
                kv.get(k)
                    .copied()
                    .ok_or_else(|| Error::parse(format!("manifest line {}: missing `{k}`", lineno + 1)))
            };
            let parse_usize = |k: &str| -> Result<usize> {
                get(k)?
                    .parse()
                    .map_err(|_| Error::parse(format!("manifest line {}: bad `{k}`", lineno + 1)))
            };
            let meta = ArtifactMeta {
                program: get("program")?.to_string(),
                name: get("name")?.to_string(),
                path: dir.join(get("file")?),
                dtype: get("dtype")?.to_string(),
                block: parse_usize("block")?,
                n: parse_usize("n")?,
                k: parse_usize("k")?,
                ins: parse_shapes(get("ins")?)?,
                outs: parse_shapes(get("outs")?)?,
            };
            m.by_program.entry(meta.program.clone()).or_default().push(meta);
            m.count += 1;
        }
        // Deterministic lookup: smallest block first.
        for v in m.by_program.values_mut() {
            v.sort_by_key(|a| (a.block, a.n, a.k));
        }
        Ok(m)
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// All artifacts of a program.
    pub fn program(&self, program: &str) -> &[ArtifactMeta] {
        self.by_program.get(program).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Artifact by exact name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_program
            .values()
            .flatten()
            .find(|a| a.name == name)
    }

    /// Find the artifact for `program` with exact `(n, k)` and the smallest
    /// `block >= rows` (rows are zero-padded up to the block).
    pub fn lookup(&self, program: &str, rows: usize, n: usize, k: usize) -> Option<&ArtifactMeta> {
        self.program(program)
            .iter()
            .filter(|a| a.n == n && a.k == k && a.block >= rows)
            .min_by_key(|a| a.block)
    }

    /// Find the eigh artifact for exactly `k`.
    pub fn lookup_eigh(&self, k: usize) -> Option<&ArtifactMeta> {
        self.program("eigh").iter().find(|a| a.k == k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
program=gram name=gram_b256_n64 file=gram_b256_n64.hlo.txt dtype=float32 block=256 n=64 k=0 ins=256x64 outs=64x64
program=gram name=gram_b512_n64 file=gram_b512_n64.hlo.txt dtype=float32 block=512 n=64 k=0 ins=512x64 outs=64x64
program=fused name=fused_b256_n64_k16 file=f.hlo.txt dtype=float32 block=256 n=64 k=16 ins=256x64,64x16 outs=256x16,16x16
program=eigh name=eigh_k16 file=eigh_k16.hlo.txt dtype=float32 block=0 n=0 k=16 ins=16x16 outs=16,16x16
";

    fn manifest() -> Manifest {
        Manifest::parse(SAMPLE, Path::new("/art")).unwrap()
    }

    #[test]
    fn parses_all_lines() {
        let m = manifest();
        assert_eq!(m.len(), 4);
        assert_eq!(m.program("gram").len(), 2);
    }

    #[test]
    fn shapes_parsed() {
        let m = manifest();
        let f = m.by_name("fused_b256_n64_k16").unwrap();
        assert_eq!(f.ins, vec![vec![256, 64], vec![64, 16]]);
        assert_eq!(f.outs, vec![vec![256, 16], vec![16, 16]]);
        let e = m.by_name("eigh_k16").unwrap();
        assert_eq!(e.outs, vec![vec![16], vec![16, 16]]);
    }

    #[test]
    fn lookup_prefers_smallest_sufficient_block() {
        let m = manifest();
        assert_eq!(m.lookup("gram", 100, 64, 0).unwrap().block, 256);
        assert_eq!(m.lookup("gram", 256, 64, 0).unwrap().block, 256);
        assert_eq!(m.lookup("gram", 300, 64, 0).unwrap().block, 512);
        assert!(m.lookup("gram", 600, 64, 0).is_none());
        assert!(m.lookup("gram", 10, 65, 0).is_none());
    }

    #[test]
    fn lookup_eigh_exact_k() {
        let m = manifest();
        assert!(m.lookup_eigh(16).is_some());
        assert!(m.lookup_eigh(32).is_none());
    }

    #[test]
    fn paths_resolved_against_dir() {
        let m = manifest();
        assert_eq!(
            m.by_name("gram_b256_n64").unwrap().path,
            Path::new("/art/gram_b256_n64.hlo.txt")
        );
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(Manifest::parse("program=x name", Path::new(".")).is_err());
        assert!(Manifest::parse("name=x file=y", Path::new(".")).is_err());
    }
}
