//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Layering (see /opt/xla-example and DESIGN.md): `python/compile/aot.py`
//! lowers the JAX/Pallas programs to HLO **text** once at build time;
//! [`engine::Engine`] compiles them on the PJRT CPU client at startup
//! (lazily, cached) and executes them with `f32` literals.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so the engine lives on a single
//! dedicated **service thread** ([`service::XlaService`]) that Split-Process
//! workers call through a cloneable, thread-safe [`service::XlaHandle`] —
//! operationally this models one shared accelerator serving all workers.

pub mod artifact;
#[cfg(feature = "xla")]
pub mod engine;
pub mod literal;
#[cfg(feature = "xla")]
pub mod service;

pub use artifact::{ArtifactMeta, Manifest};
#[cfg(feature = "xla")]
pub use engine::Engine;
#[cfg(feature = "xla")]
pub use service::{XlaHandle, XlaService};
