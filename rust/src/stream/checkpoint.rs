//! Sketch-state checkpointing: resume a stream from the last batch boundary.
//!
//! A checkpoint is the full [`SketchState`] (G, W, per-epoch statistics)
//! plus the shard registry (which on-disk `SY` shard belongs to which
//! epoch) — everything needed to continue absorbing rows as if the process
//! had never died. The `Y` shards themselves are already durable (staged
//! writes, atomic rename), so a resume re-reads nothing it already has.
//!
//! Layout under the work dir, all binmat (bit-exact f64):
//!
//! ```text
//! stream.ckpt            key=value manifest, written last via tmp+rename
//! ckpt-G.bin ckpt-W.bin  the two accumulators
//! ckpt-ep<e>-cs.bin      epoch e column sums       (1 x n)
//! ckpt-ep<e>-sy.bin      epoch e sketch-row sum    (1 x width)
//! ckpt-ep<e>-map.bin     epoch e extension map     (w_e x width, closed only)
//! ```
//!
//! The manifest is the commit record: matrices are written (tmp + rename)
//! first, the manifest last, so a crash mid-checkpoint leaves the previous
//! complete checkpoint intact. `fro2` travels as `f64::to_bits` so the
//! resumed accumulator is bit-identical.
//!
//! On resume the *source* must be replayed to the checkpointed row count:
//! a regular file is simply re-read and skipped ([`super::StreamSource::skip_rows`]);
//! a pipe or socket needs its producer to restart from the beginning (or
//! from the last acknowledged batch) — the checkpoint records how many rows
//! are already absorbed either way.

use super::sketch::{Epoch, SketchState};
use crate::error::{Error, Result};
use crate::io::binmat::{read_matrix_bin, write_matrix_bin};
use crate::io::manifest::KvManifest;
use crate::linalg::Matrix;
use std::path::Path;

const MANIFEST: &str = "stream.ckpt";

fn path_of(dir: &str, name: &str) -> String {
    Path::new(dir).join(name).to_string_lossy().into_owned()
}

/// Write a matrix atomically (tmp sibling + rename).
fn write_atomic(m: &Matrix, path: &str) -> Result<()> {
    let tmp = format!("{path}.tmp-{}", std::process::id());
    write_matrix_bin(m, &tmp)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

fn row_matrix(v: &[f64]) -> Matrix {
    Matrix::from_fn(1, v.len().max(1), |_, j| v.get(j).copied().unwrap_or(0.0))
}

/// Persist the sketch and shard registry under `dir`.
pub fn save(dir: &str, sketch: &SketchState, shard_epochs: &[u32]) -> Result<()> {
    write_atomic(&sketch.g, &path_of(dir, "ckpt-G.bin"))?;
    write_atomic(&sketch.w, &path_of(dir, "ckpt-W.bin"))?;
    for (e, ep) in sketch.epochs.iter().enumerate() {
        write_atomic(&row_matrix(&ep.colsums), &path_of(dir, &format!("ckpt-ep{e}-cs.bin")))?;
        write_atomic(&row_matrix(&ep.s_y), &path_of(dir, &format!("ckpt-ep{e}-sy.bin")))?;
        if let Some(map) = &ep.map {
            write_atomic(map, &path_of(dir, &format!("ckpt-ep{e}-map.bin")))?;
        }
    }
    let mut m = KvManifest::new();
    m.set("version", 1);
    m.set("seed", sketch.seed);
    m.set("rows", sketch.rows);
    m.set("n", sketch.n);
    m.set("width", sketch.width);
    m.set("fro2_bits", sketch.fro2.to_bits());
    m.set("epochs", sketch.epochs.len());
    for (e, ep) in sketch.epochs.iter().enumerate() {
        m.set(&format!("epoch{e}_width"), ep.width);
        m.set(&format!("epoch{e}_rows"), ep.rows);
    }
    let eps: Vec<String> = shard_epochs.iter().map(|e| e.to_string()).collect();
    m.set("shards", shard_epochs.len());
    m.set("shard_epochs", eps.join(","));
    let dst = path_of(dir, MANIFEST);
    let tmp = format!("{dst}.tmp-{}", std::process::id());
    m.save(&tmp)?;
    std::fs::rename(&tmp, &dst)?;
    Ok(())
}

/// Load a checkpoint if one exists. `seed` must match the checkpointed Ω
/// seed — a different seed means the on-disk sketch belongs to a different
/// projection and silently mixing them would corrupt the factors.
pub fn load(dir: &str, seed: u64) -> Result<Option<(SketchState, Vec<u32>)>> {
    let manifest_path = path_of(dir, MANIFEST);
    if !Path::new(&manifest_path).exists() {
        return Ok(None);
    }
    let m = KvManifest::load(&manifest_path)?;
    let ck_seed = m
        .get_u64("seed")?
        .ok_or_else(|| Error::parse("checkpoint: missing seed"))?;
    if ck_seed != seed {
        return Err(Error::Config(format!(
            "checkpoint in {dir} was written with seed {ck_seed}, run uses seed {seed} — \
             pass the original seed or clear the work dir"
        )));
    }
    let rows = m
        .get_u64("rows")?
        .ok_or_else(|| Error::parse("checkpoint: missing rows"))?;
    let n = m.require_usize("n")?;
    let width = m.require_usize("width")?;
    let fro2 = f64::from_bits(
        m.get_u64("fro2_bits")?
            .ok_or_else(|| Error::parse("checkpoint: missing fro2_bits"))?,
    );
    let g = read_matrix_bin(&path_of(dir, "ckpt-G.bin"))?;
    let w = read_matrix_bin(&path_of(dir, "ckpt-W.bin"))?;
    if g.shape() != (width, width) || w.shape() != (n, width) {
        return Err(Error::shape(format!(
            "checkpoint: G {:?} / W {:?} disagree with manifest ({n}, {width})",
            g.shape(),
            w.shape()
        )));
    }
    let n_epochs = m.require_usize("epochs")?;
    let mut epochs = Vec::with_capacity(n_epochs);
    for e in 0..n_epochs {
        let ep_width = m.require_usize(&format!("epoch{e}_width"))?;
        let ep_rows = m
            .get_u64(&format!("epoch{e}_rows"))?
            .ok_or_else(|| Error::parse(format!("checkpoint: missing epoch{e}_rows")))?;
        let cs = read_matrix_bin(&path_of(dir, &format!("ckpt-ep{e}-cs.bin")))?;
        let sy = read_matrix_bin(&path_of(dir, &format!("ckpt-ep{e}-sy.bin")))?;
        let mut colsums = cs.row(0).to_vec();
        colsums.resize(n, 0.0); // a 0-col epoch serializes as 1x1
        let mut s_y = sy.row(0).to_vec();
        s_y.resize(width, 0.0);
        let map_path = path_of(dir, &format!("ckpt-ep{e}-map.bin"));
        let map = if e + 1 < n_epochs {
            Some(read_matrix_bin(&map_path)?)
        } else {
            None
        };
        epochs.push(Epoch { width: ep_width, rows: ep_rows, colsums, s_y, map });
    }
    let shard_epochs: Vec<u32> = m
        .require_usize_list("shard_epochs")
        .map(|v| v.into_iter().map(|e| e as u32).collect())
        .or_else(|_| {
            // A zero-shard checkpoint renders as an empty value.
            if m.require_usize("shards")? == 0 {
                Ok(Vec::new())
            } else {
                Err(Error::parse("checkpoint: bad shard_epochs"))
            }
        })?;
    if shard_epochs.iter().any(|&e| e as usize >= n_epochs) {
        return Err(Error::parse("checkpoint: shard references unknown epoch"));
    }
    Ok(Some((
        SketchState::from_parts(seed, fro2, rows, g, w, epochs),
        shard_epochs,
    )))
}

/// Remove all checkpoint files under `dir` (best effort, e.g. after a
/// successful run or an explicit fresh start).
pub fn clear(dir: &str) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name == MANIFEST || name.starts_with("ckpt-") {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;

    fn tmp_dir(name: &str) -> String {
        let dir = std::env::temp_dir().join("tallfat_test_stream_ckpt").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir.to_string_lossy().into_owned()
    }

    #[test]
    fn roundtrip_preserves_sketch_bit_exactly() {
        let be = NativeBackend::new();
        let a = Matrix::from_fn(30, 12, |i, j| ((i * 31 + j * 7) % 13) as f64 - 6.0);
        let mut sk = SketchState::new(17, 12, 5);
        sk.absorb_dense(&a.slice_rows(0, 15), &be).unwrap();
        sk.widen(3, 1e-7, &be).unwrap();
        sk.absorb_dense(&a.slice_rows(15, 30), &be).unwrap();

        let dir = tmp_dir("roundtrip");
        save(&dir, &sk, &[0, 1]).unwrap();
        let (back, shard_epochs) = load(&dir, 17).unwrap().unwrap();
        assert_eq!(shard_epochs, vec![0, 1]);
        assert_eq!(back.rows(), sk.rows());
        assert_eq!(back.width(), sk.width());
        assert_eq!(back.cols(), sk.cols());
        assert_eq!(back.g.max_abs_diff(&sk.g), 0.0);
        assert_eq!(back.w.max_abs_diff(&sk.w), 0.0);
        assert_eq!(back.epochs.len(), 2);
        assert_eq!(back.epochs[0].rows, 15);
        assert_eq!(back.epochs[0].s_y, sk.epochs[0].s_y);
        assert_eq!(back.epochs[0].colsums, sk.epochs[0].colsums);
        assert_eq!(
            back.epochs[0]
                .map
                .as_ref()
                .unwrap()
                .max_abs_diff(sk.epochs[0].map.as_ref().unwrap()),
            0.0
        );
        assert!(back.epochs[1].map.is_none());

        // Resumed absorption continues identically.
        let mut again = back;
        let extra = Matrix::from_fn(5, 12, |i, j| (i + j) as f64);
        let y1 = again.absorb_dense(&extra, &be).unwrap();
        let y2 = sk.absorb_dense(&extra, &be).unwrap();
        assert_eq!(y1.max_abs_diff(&y2), 0.0);
        assert_eq!(again.g.max_abs_diff(&sk.g), 0.0);
    }

    #[test]
    fn missing_checkpoint_is_none_and_seed_mismatch_errors() {
        let dir = tmp_dir("missing");
        assert!(load(&dir, 1).unwrap().is_none());
        let sk = SketchState::new(5, 4, 3);
        save(&dir, &sk, &[]).unwrap();
        assert!(load(&dir, 6).is_err(), "seed mismatch must refuse to resume");
        assert!(load(&dir, 5).unwrap().is_some());
        clear(&dir);
        assert!(load(&dir, 5).unwrap().is_none());
    }
}
