//! Sketch-state checkpointing: resume a stream from the last batch boundary.
//!
//! A checkpoint is the full [`SketchState`] (G, W, per-epoch statistics)
//! plus the shard registry (which on-disk `SY` shard belongs to which
//! epoch) — everything needed to continue absorbing rows as if the process
//! had never died. The `Y` shards themselves are already durable (staged
//! writes, atomic rename), so a resume re-reads nothing it already has.
//!
//! Layout under the work dir, all binmat (bit-exact f64):
//!
//! ```text
//! stream.ckpt                key=value manifest, written last via tmp+rename
//! ckpt-g<s>-G.bin ...-W.bin  save s: the two accumulators
//! ckpt-g<s>-ep<e>-cs.bin     save s, epoch e column sums    (1 x n)
//! ckpt-g<s>-ep<e>-sy.bin     save s, epoch e sketch-row sum (1 x width)
//! ckpt-g<s>-ep<e>-map.bin    save s, epoch e extension map  (closed only)
//! ```
//!
//! The manifest is the commit record and each save writes a *fresh
//! generation* of state files (`save_gen` in the manifest names it): a
//! crash anywhere before the manifest rename leaves the previous
//! checkpoint's files untouched and still referenced, so a resume can
//! never pair an old row count with newer accumulators. Superseded
//! generations are garbage-collected only after the rename. `fro2`
//! travels as `f64::to_bits` so the resumed accumulator is bit-identical.
//!
//! On resume the *source* must be replayed to the checkpointed row count:
//! a regular file is simply re-read and skipped ([`super::StreamSource::skip_rows`]);
//! a pipe or socket needs its producer to restart from the beginning (or
//! from the last acknowledged batch) — the checkpoint records how many rows
//! are already absorbed either way.

use super::sketch::{Epoch, SketchState};
use crate::error::{Error, Result};
use crate::io::binmat::{read_matrix_bin, write_matrix_bin};
use crate::io::manifest::KvManifest;
use crate::linalg::Matrix;
use std::path::Path;

const MANIFEST: &str = "stream.ckpt";

fn path_of(dir: &str, name: &str) -> String {
    Path::new(dir).join(name).to_string_lossy().into_owned()
}

/// Write a matrix atomically (tmp sibling + rename).
fn write_atomic(m: &Matrix, path: &str) -> Result<()> {
    let tmp = format!("{path}.tmp-{}", std::process::id());
    write_matrix_bin(m, &tmp)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

fn row_matrix(v: &[f64]) -> Matrix {
    Matrix::from_fn(1, v.len().max(1), |_, j| v.get(j).copied().unwrap_or(0.0))
}

/// `ckpt-g<s>-<name>` for save generation `s`.
fn gen_file(dir: &str, gen: u64, name: &str) -> String {
    path_of(dir, &format!("ckpt-g{gen}-{name}"))
}

/// Parse the save generation out of a `ckpt-g<s>-...` file name.
fn parse_gen(name: &str) -> Option<u64> {
    let rest = name.strip_prefix("ckpt-g")?;
    rest[..rest.find('-')?].parse().ok()
}

/// Next unused save generation: one past the largest on disk, so a new
/// save can never overwrite files a crashed or concurrent save's manifest
/// might still reference.
fn next_save_gen(dir: &str) -> u64 {
    let Ok(entries) = std::fs::read_dir(dir) else { return 1 };
    entries
        .flatten()
        .filter_map(|e| parse_gen(&e.file_name().to_string_lossy()))
        .max()
        .unwrap_or(0)
        + 1
}

/// Write save generation `gen`'s state files (everything but the manifest).
fn write_state_files(dir: &str, gen: u64, sketch: &SketchState) -> Result<()> {
    write_atomic(&sketch.g, &gen_file(dir, gen, "G.bin"))?;
    write_atomic(&sketch.w, &gen_file(dir, gen, "W.bin"))?;
    for (e, ep) in sketch.epochs.iter().enumerate() {
        write_atomic(&row_matrix(&ep.colsums), &gen_file(dir, gen, &format!("ep{e}-cs.bin")))?;
        write_atomic(&row_matrix(&ep.s_y), &gen_file(dir, gen, &format!("ep{e}-sy.bin")))?;
        if let Some(map) = &ep.map {
            write_atomic(map, &gen_file(dir, gen, &format!("ep{e}-map.bin")))?;
        }
    }
    Ok(())
}

/// Best-effort removal of every state file not belonging to `keep`.
fn gc_state_files(dir: &str, keep: u64) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with("ckpt-") && parse_gen(&name) != Some(keep) {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

/// Persist the sketch and shard registry under `dir`.
pub fn save(dir: &str, sketch: &SketchState, shard_epochs: &[u32]) -> Result<()> {
    let gen = next_save_gen(dir);
    write_state_files(dir, gen, sketch)?;
    let mut m = KvManifest::new();
    m.set("version", 2);
    m.set("save_gen", gen);
    m.set("seed", sketch.seed);
    m.set("rows", sketch.rows);
    m.set("n", sketch.n);
    m.set("width", sketch.width);
    m.set("fro2_bits", sketch.fro2.to_bits());
    m.set("epochs", sketch.epochs.len());
    for (e, ep) in sketch.epochs.iter().enumerate() {
        m.set(&format!("epoch{e}_width"), ep.width);
        m.set(&format!("epoch{e}_rows"), ep.rows);
    }
    let eps: Vec<String> = shard_epochs.iter().map(|e| e.to_string()).collect();
    m.set("shards", shard_epochs.len());
    m.set("shard_epochs", eps.join(","));
    let dst = path_of(dir, MANIFEST);
    let tmp = format!("{dst}.tmp-{}", std::process::id());
    m.save(&tmp)?;
    std::fs::rename(&tmp, &dst)?;
    // Committed — only now is the previous generation unreferenced.
    gc_state_files(dir, gen);
    Ok(())
}

/// Load a checkpoint if one exists. `seed` must match the checkpointed Ω
/// seed — a different seed means the on-disk sketch belongs to a different
/// projection and silently mixing them would corrupt the factors.
pub fn load(dir: &str, seed: u64) -> Result<Option<(SketchState, Vec<u32>)>> {
    let manifest_path = path_of(dir, MANIFEST);
    if !Path::new(&manifest_path).exists() {
        return Ok(None);
    }
    let m = KvManifest::load(&manifest_path)?;
    let ck_seed = m
        .get_u64("seed")?
        .ok_or_else(|| Error::parse("checkpoint: missing seed"))?;
    if ck_seed != seed {
        return Err(Error::Config(format!(
            "checkpoint in {dir} was written with seed {ck_seed}, run uses seed {seed} — \
             pass the original seed or clear the work dir"
        )));
    }
    let rows = m
        .get_u64("rows")?
        .ok_or_else(|| Error::parse("checkpoint: missing rows"))?;
    // The manifest names the exact save generation it committed, so the
    // files read here are always the ones written together with it.
    let gen = m
        .get_u64("save_gen")?
        .ok_or_else(|| Error::parse("checkpoint: missing save_gen (pre-v2 format?)"))?;
    let n = m.require_usize("n")?;
    let width = m.require_usize("width")?;
    let fro2 = f64::from_bits(
        m.get_u64("fro2_bits")?
            .ok_or_else(|| Error::parse("checkpoint: missing fro2_bits"))?,
    );
    let g = read_matrix_bin(&gen_file(dir, gen, "G.bin"))?;
    let w = read_matrix_bin(&gen_file(dir, gen, "W.bin"))?;
    if g.shape() != (width, width) || w.shape() != (n, width) {
        return Err(Error::shape(format!(
            "checkpoint: G {:?} / W {:?} disagree with manifest ({n}, {width})",
            g.shape(),
            w.shape()
        )));
    }
    let n_epochs = m.require_usize("epochs")?;
    let mut epochs = Vec::with_capacity(n_epochs);
    for e in 0..n_epochs {
        let ep_width = m.require_usize(&format!("epoch{e}_width"))?;
        let ep_rows = m
            .get_u64(&format!("epoch{e}_rows"))?
            .ok_or_else(|| Error::parse(format!("checkpoint: missing epoch{e}_rows")))?;
        let cs = read_matrix_bin(&gen_file(dir, gen, &format!("ep{e}-cs.bin")))?;
        let sy = read_matrix_bin(&gen_file(dir, gen, &format!("ep{e}-sy.bin")))?;
        let mut colsums = cs.row(0).to_vec();
        colsums.resize(n, 0.0); // a 0-col epoch serializes as 1x1
        let mut s_y = sy.row(0).to_vec();
        s_y.resize(width, 0.0);
        let map_path = gen_file(dir, gen, &format!("ep{e}-map.bin"));
        let map = if e + 1 < n_epochs {
            Some(read_matrix_bin(&map_path)?)
        } else {
            None
        };
        epochs.push(Epoch { width: ep_width, rows: ep_rows, colsums, s_y, map });
    }
    let shard_epochs: Vec<u32> = m
        .require_usize_list("shard_epochs")
        .map(|v| v.into_iter().map(|e| e as u32).collect())
        .or_else(|_| {
            // A zero-shard checkpoint renders as an empty value.
            if m.require_usize("shards")? == 0 {
                Ok(Vec::new())
            } else {
                Err(Error::parse("checkpoint: bad shard_epochs"))
            }
        })?;
    if shard_epochs.iter().any(|&e| e as usize >= n_epochs) {
        return Err(Error::parse("checkpoint: shard references unknown epoch"));
    }
    Ok(Some((
        SketchState::from_parts(seed, fro2, rows, g, w, epochs),
        shard_epochs,
    )))
}

/// Remove all checkpoint files under `dir` (best effort, e.g. after a
/// successful run or an explicit fresh start).
pub fn clear(dir: &str) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name == MANIFEST || name.starts_with("ckpt-") {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;

    fn tmp_dir(name: &str) -> String {
        let dir = std::env::temp_dir().join("tallfat_test_stream_ckpt").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir.to_string_lossy().into_owned()
    }

    #[test]
    fn roundtrip_preserves_sketch_bit_exactly() {
        let be = NativeBackend::new();
        let a = Matrix::from_fn(30, 12, |i, j| ((i * 31 + j * 7) % 13) as f64 - 6.0);
        let mut sk = SketchState::new(17, 12, 5);
        sk.absorb_dense(&a.slice_rows(0, 15), &be).unwrap();
        sk.widen(3, 1e-7, &be).unwrap();
        sk.absorb_dense(&a.slice_rows(15, 30), &be).unwrap();

        let dir = tmp_dir("roundtrip");
        save(&dir, &sk, &[0, 1]).unwrap();
        let (back, shard_epochs) = load(&dir, 17).unwrap().unwrap();
        assert_eq!(shard_epochs, vec![0, 1]);
        assert_eq!(back.rows(), sk.rows());
        assert_eq!(back.width(), sk.width());
        assert_eq!(back.cols(), sk.cols());
        assert_eq!(back.g.max_abs_diff(&sk.g), 0.0);
        assert_eq!(back.w.max_abs_diff(&sk.w), 0.0);
        assert_eq!(back.epochs.len(), 2);
        assert_eq!(back.epochs[0].rows, 15);
        assert_eq!(back.epochs[0].s_y, sk.epochs[0].s_y);
        assert_eq!(back.epochs[0].colsums, sk.epochs[0].colsums);
        assert_eq!(
            back.epochs[0]
                .map
                .as_ref()
                .unwrap()
                .max_abs_diff(sk.epochs[0].map.as_ref().unwrap()),
            0.0
        );
        assert!(back.epochs[1].map.is_none());

        // Resumed absorption continues identically.
        let mut again = back;
        let extra = Matrix::from_fn(5, 12, |i, j| (i + j) as f64);
        let y1 = again.absorb_dense(&extra, &be).unwrap();
        let y2 = sk.absorb_dense(&extra, &be).unwrap();
        assert_eq!(y1.max_abs_diff(&y2), 0.0);
        assert_eq!(again.g.max_abs_diff(&sk.g), 0.0);
    }

    /// A crash after the new save's state files land but before the
    /// manifest rename must leave the previous checkpoint fully intact —
    /// resuming from it and re-absorbing the lost batch must be identical
    /// to never having crashed (no double-counted rows).
    #[test]
    fn crash_before_manifest_commit_keeps_previous_checkpoint() {
        let be = NativeBackend::new();
        let a = Matrix::from_fn(40, 10, |i, j| ((i * 17 + j * 5) % 11) as f64 - 5.0);
        let mut sk = SketchState::new(23, 10, 4);
        sk.absorb_dense(&a.slice_rows(0, 20), &be).unwrap();
        let dir = tmp_dir("crash");
        save(&dir, &sk, &[0]).unwrap();
        let committed_g = sk.g.clone();

        // The crashing save: absorb one more batch, write the next
        // generation's state files... and die before the manifest rename.
        sk.absorb_dense(&a.slice_rows(20, 30), &be).unwrap();
        let gen = next_save_gen(&dir);
        write_state_files(&dir, gen, &sk).unwrap();

        let (back, _) = load(&dir, 23).unwrap().unwrap();
        assert_eq!(back.rows(), 20, "must resume at the committed row count");
        assert_eq!(
            back.g.max_abs_diff(&committed_g),
            0.0,
            "accumulators must match the committed rows, not the torn save"
        );

        // Replaying rows 20.. from the loaded state converges with the
        // uninterrupted sketch — nothing was absorbed twice.
        let mut resumed = back;
        resumed.absorb_dense(&a.slice_rows(20, 30), &be).unwrap();
        assert_eq!(resumed.rows(), sk.rows());
        assert_eq!(resumed.g.max_abs_diff(&sk.g), 0.0);

        // A completed save commits and GCs the superseded generation.
        save(&dir, &resumed, &[0, 1]).unwrap();
        let (again, _) = load(&dir, 23).unwrap().unwrap();
        assert_eq!(again.rows(), 30);
        let keep = next_save_gen(&dir) - 1;
        let stale: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with("ckpt-") && parse_gen(n) != Some(keep))
            .collect();
        assert!(stale.is_empty(), "stale generations not GC'd: {stale:?}");
    }

    #[test]
    fn missing_checkpoint_is_none_and_seed_mismatch_errors() {
        let dir = tmp_dir("missing");
        assert!(load(&dir, 1).unwrap().is_none());
        let sk = SketchState::new(5, 4, 3);
        save(&dir, &sk, &[]).unwrap();
        assert!(load(&dir, 6).is_err(), "seed mismatch must refuse to resume");
        assert!(load(&dir, 5).unwrap().is_some());
        clear(&dir);
        assert!(load(&dir, 5).unwrap().is_none());
    }
}
