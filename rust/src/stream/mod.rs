//! One-pass streaming SVD over non-seekable sources.
//!
//! Every multi-pass route in [`crate::svd`] re-reads the input (projection,
//! U recovery, power iterations), so `Svd::over` requires a seekable file.
//! This module factorizes from a *single forward pass* — stdin, a pipe, a
//! socket, or any [`std::io::Read`] — using the Halko–Martinsson–Tropp
//! one-pass sketch (arXiv 0909.4061 §5.5):
//!
//! ```text
//! per batch   Y_b = A_b Ω           k'-wide projection of the batch rows
//!             G  += Y_bᵀ Y_b        k' x k'   (= YᵀY over all rows)
//!             W  += A_bᵀ Y_b        n  x k'   (= AᵀY)
//!             Y_b → shard on disk   (k'-wide rows, never the input rows)
//! finish      eigh(G) → M = V_y Σ_y⁻¹;  Wp = W M  (≡ AᵀU0, U0 = Y M)
//!             eigh(WpᵀWp) → σ, P;  V = Wp P Σ⁻¹;  U rows = y (M P) per shard
//! ```
//!
//! With the same seed and sketch width this recovers *exactly* the factors
//! of the multi-pass randomized route at `power_iters = 0` — the shared
//! leader math is identical; only where `AᵀU0` comes from differs
//! (`(AᵀY)M` here, a second pass there).
//!
//! ## Adaptive rank
//!
//! The sketch width is not guessed up front: [`StreamSvd`] starts narrow
//! and monitors the a posteriori residual estimate
//! `‖A − U0U0ᵀA‖_F² = ‖A‖_F² − ‖W M‖_F²` at every batch boundary (the
//! adaptive range-finder idea of arXiv 1607.01649). While the relative
//! residual exceeds `tol` and rows keep arriving, Ω is widened — *reusing
//! the accumulated sketch state, never the rows*: already-seen rows'
//! contribution to the new columns is reconstructed through the current
//! basis (`Y_new ≈ Y·M Mᵀ WᵀΩ_add`), and rows that arrive after the
//! widening are projected against the wider Ω exactly. Per-epoch extension
//! maps keep the on-disk Y shards (written at their epoch's width)
//! convertible to the final width at recovery time.
//!
//! ## Accuracy trade-off
//!
//! One pass costs accuracy relative to the multi-pass routes: rows seen
//! *before* a widening only contribute to the new sketch columns through
//! the basis captured so far, and there is no power iteration. For spectra
//! with decent decay the σ error is within the residual target; for flat
//! spectra prefer the multi-pass `tallfat svd` with `--power-iters`.
//! `benches/bench_stream.rs` quantifies the gap.
//!
//! ## Centering (PCA mode)
//!
//! Column means are accumulated during the same single pass and applied as
//! exact rank-1 corrections to `G`, `W` and the Frobenius mass at
//! estimate/recovery time — no extra pass, no densified rows.
//!
//! Wired end to end: `tallfat stream` (CLI), a `stream` daemon job kind
//! that merges the factors into a served model as a new generation
//! ([`crate::update::merge_factored`]), sketch-state checkpointing for
//! resume at the last batch boundary ([`checkpoint`]), and `stream_*`
//! gauges in the metrics registry.

pub mod builder;
pub mod checkpoint;
pub mod sketch;
pub mod source;

pub use builder::StreamSvd;
pub use sketch::SketchState;
pub use source::{Batch, StreamSource};

/// Default relative residual target for the adaptive range finder.
pub const DEFAULT_TOL: f64 = 1e-3;

/// Default rank ceiling when neither `--max-rank` nor `--k` is given.
pub const DEFAULT_MAX_RANK: usize = 512;

/// Default rows per absorbed batch.
pub const DEFAULT_BATCH_ROWS: usize = 1024;

/// Default initial sketch width of the adaptive finder.
pub const DEFAULT_START_WIDTH: usize = 16;

/// Default minimum time between checkpoint writes. Checkpoints land only
/// at batch boundaries; the cadence keeps O(n·width) checkpoint I/O from
/// dominating absorb time when batches are small or sparse.
pub const DEFAULT_CHECKPOINT_INTERVAL: std::time::Duration =
    std::time::Duration::from_secs(5);
