//! The fluent one-pass streaming SVD driver.
//!
//! ```ignore
//! use tallfat::stream::StreamSvd;
//! let result = StreamSvd::from(reader)     // any io::Read — pipe, socket…
//!     .tol(1e-3)
//!     .max_rank(512)
//!     .batch_rows(1024)
//!     .center(true)
//!     .run()?;                             // exactly one forward pass
//! ```
//!
//! Rows are consumed in batches; each batch updates the k'-sized
//! [`SketchState`] and writes its `Y` block to a disk shard. At every full
//! batch boundary the a posteriori residual estimate decides whether Ω
//! widens ([`SketchState::widen`] — state-only, rows are never revisited).
//! At end of stream the factorization is recovered on the leader from the
//! sketch and the `Y` shards rotate into `U` shards, yielding the same
//! [`SvdResult`] the multi-pass routes produce — `--save-model`, `tallfat
//! serve`, and the update/merge path all work on it unchanged.

use super::checkpoint;
use super::sketch::SketchState;
use super::source::{Batch, StreamSource};
use crate::backend::{native::NativeBackend, BackendRef};
use crate::config::InputFormat;
use crate::coordinator::server::MetricsRegistry;
use crate::error::{Error, Result};
use crate::io::writer::ShardSet;
use crate::metrics::PhaseReport;
use crate::svd::{SvdResult, DEFAULT_SIGMA_CUTOFF_REL};
use std::io::Read;
use std::sync::Arc;
use std::time::{Duration, Instant};

enum Source {
    Path(String),
    Reader(Box<dyn Read + Send>),
}

/// Progress callback: `(rows_absorbed, sketch_width)` after every batch.
pub type ProgressFn = Box<dyn FnMut(u64, usize) + Send>;

/// Builder for a one-pass streaming SVD — see the module docs.
pub struct StreamSvd {
    source: Source,
    format: Option<InputFormat>,
    tol: f64,
    max_rank: usize,
    batch_rows: usize,
    start_width: usize,
    oversample: usize,
    rank: Option<usize>,
    center: bool,
    seed: u64,
    cols: usize,
    work_dir: String,
    backend: Option<BackendRef>,
    sigma_cutoff_rel: f64,
    checkpoint: bool,
    checkpoint_interval: Duration,
    resume: bool,
    save_model: Option<String>,
    progress: Option<ProgressFn>,
}

/// `StreamSvd::from(reader)` — factor any forward-only byte stream
/// (default framing: csv; override with [`StreamSvd::format`]).
impl<R: Read + Send + 'static> From<R> for StreamSvd {
    fn from(reader: R) -> Self {
        StreamSvd::with_source(Source::Reader(Box::new(reader)))
    }
}

impl StreamSvd {
    fn with_source(source: Source) -> Self {
        StreamSvd {
            source,
            format: None,
            tol: super::DEFAULT_TOL,
            max_rank: 0,
            batch_rows: super::DEFAULT_BATCH_ROWS,
            start_width: super::DEFAULT_START_WIDTH,
            oversample: 8,
            rank: None,
            center: false,
            seed: 0,
            cols: 0,
            work_dir: std::env::temp_dir()
                .join("tallfat_stream")
                .to_string_lossy()
                .into_owned(),
            backend: None,
            sigma_cutoff_rel: DEFAULT_SIGMA_CUTOFF_REL,
            checkpoint: false,
            checkpoint_interval: super::DEFAULT_CHECKPOINT_INTERVAL,
            resume: false,
            save_model: None,
            progress: None,
        }
    }

    /// Stream from a path: `-` is stdin; a FIFO/pipe path blocks until a
    /// producer connects. Framing defaults to the path's extension.
    pub fn open(path: impl Into<String>) -> Self {
        StreamSvd::with_source(Source::Path(path.into()))
    }

    /// Input framing (csv / bin / libsvm / scsv / csr).
    pub fn format(mut self, format: InputFormat) -> Self {
        self.format = Some(format);
        self
    }

    /// Target relative residual for the adaptive range finder.
    pub fn tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    /// Rank ceiling for the adaptive finder (0 = [`super::DEFAULT_MAX_RANK`]).
    pub fn max_rank(mut self, max_rank: usize) -> Self {
        self.max_rank = max_rank;
        self
    }

    /// Rows absorbed per batch.
    pub fn batch_rows(mut self, batch_rows: usize) -> Self {
        self.batch_rows = batch_rows;
        self
    }

    /// Initial sketch width of the adaptive finder.
    pub fn start_width(mut self, start_width: usize) -> Self {
        self.start_width = start_width;
        self
    }

    /// Sketch oversampling on top of the (maximum) rank.
    pub fn oversample(mut self, oversample: usize) -> Self {
        self.oversample = oversample;
        self
    }

    /// Pin the output rank (disables adaptive widening; the sketch runs at
    /// `rank + oversample` throughout — multi-pass parity mode).
    pub fn rank(mut self, k: usize) -> Self {
        self.rank = Some(k);
        self
    }

    /// PCA mode: factor `A - 1μᵀ`, with μ accumulated in the same pass.
    pub fn center(mut self, center: bool) -> Self {
        self.center = center;
        self
    }

    /// Ω seed (must match across resume).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Pin the column dictionary width (sparse streams; required when the
    /// factors must align with an existing model's columns).
    pub fn cols(mut self, n: usize) -> Self {
        self.cols = n;
        self
    }

    /// Directory for Y/U shards and checkpoints.
    pub fn work_dir(mut self, dir: impl Into<String>) -> Self {
        self.work_dir = dir.into();
        self
    }

    /// Compute backend (default: native).
    pub fn backend(mut self, backend: BackendRef) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Relative cutoff for the sketch-stage guarded inverse.
    pub fn sigma_cutoff_rel(mut self, cutoff: f64) -> Self {
        self.sigma_cutoff_rel = cutoff;
        self
    }

    /// Persist the sketch at batch boundaries so a crashed run resumes
    /// from the last checkpointed boundary (cadence:
    /// [`StreamSvd::checkpoint_interval`]).
    pub fn checkpoint(mut self, on: bool) -> Self {
        self.checkpoint = on;
        self
    }

    /// Minimum time between checkpoint writes (default
    /// [`super::DEFAULT_CHECKPOINT_INTERVAL`]; zero = every batch).
    /// Checkpoints still land only at batch boundaries — a longer cadence
    /// trades more replay on resume for less O(n·width) checkpoint I/O
    /// per absorbed batch.
    pub fn checkpoint_interval(mut self, interval: Duration) -> Self {
        self.checkpoint_interval = interval;
        self
    }

    /// Resume from a checkpoint in the work dir (the source must replay
    /// from its beginning; already-absorbed rows are skipped, their `Y`
    /// shards are reused from disk).
    pub fn resume(mut self, on: bool) -> Self {
        self.resume = on;
        self
    }

    /// Save the factors as a servable model directory after the run.
    pub fn save_model(mut self, dir: impl Into<String>) -> Self {
        self.save_model = Some(dir.into());
        self
    }

    /// Per-batch progress callback `(rows_absorbed, width)` — e.g. a daemon
    /// job heartbeat.
    pub fn progress(mut self, f: impl FnMut(u64, usize) + Send + 'static) -> Self {
        self.progress = Some(Box::new(f));
        self
    }

    fn validate(&self) -> Result<()> {
        if !(self.tol > 0.0 && self.tol.is_finite()) {
            return Err(Error::Config(format!(
                "tol must be a positive finite residual target, got {}",
                self.tol
            )));
        }
        if self.batch_rows == 0 {
            return Err(Error::Config("batch_rows must be >= 1".into()));
        }
        if self.start_width == 0 {
            return Err(Error::Config("start_width must be >= 1".into()));
        }
        if !(0.0..1.0).contains(&self.sigma_cutoff_rel) {
            return Err(Error::Config(format!(
                "sigma_cutoff_rel must be in [0, 1), got {}",
                self.sigma_cutoff_rel
            )));
        }
        if let Some(k) = self.rank {
            if k == 0 {
                return Err(Error::Config("rank must be >= 1".into()));
            }
            if self.max_rank != 0 && self.max_rank < k {
                return Err(Error::Config(format!(
                    "max_rank ({}) must be >= rank ({k})",
                    self.max_rank
                )));
            }
        }
        Ok(())
    }

    /// Consume the stream in one forward pass and recover the factors.
    pub fn run(mut self) -> Result<SvdResult> {
        self.validate()?;
        let backend: BackendRef =
            self.backend.take().unwrap_or_else(|| Arc::new(NativeBackend::new()));
        let format = match (&self.source, self.format) {
            (_, Some(f)) => f,
            (Source::Path(p), None) if p != "-" => InputFormat::from_path(p),
            _ => InputFormat::Csv,
        };
        let mut source = match self.source {
            Source::Path(p) => StreamSource::open(&p, format)?,
            Source::Reader(r) => StreamSource::from_reader(r, format),
        };
        if self.cols > 0 {
            source.pin_cols(self.cols);
        }
        std::fs::create_dir_all(&self.work_dir)?;
        crate::io::writer::sweep_stale_stages(&self.work_dir);
        let sy = ShardSet::new(&self.work_dir, "SY", InputFormat::Bin)?;
        let metrics = MetricsRegistry::global();
        let mut report = PhaseReport::new();
        let mut progress = self.progress.take();

        let mut sketch: Option<SketchState> = None;
        let mut shard_epochs: Vec<u32> = Vec::new();
        if self.resume {
            let t0 = Instant::now();
            if let Some((sk, eps)) = checkpoint::load(&self.work_dir, self.seed)? {
                // Replay in chunks so a long skip keeps the progress
                // callback (and any supervisor heartbeat behind it) alive.
                let mut remaining = sk.rows();
                while remaining > 0 {
                    let chunk = remaining.min(64 * 1024);
                    source.skip_rows(chunk)?;
                    remaining -= chunk;
                    if let Some(cb) = progress.as_mut() {
                        cb(sk.rows() - remaining, sk.width());
                    }
                }
                report.push("stream.resume_skip", t0.elapsed(), sk.rows(), 0);
                shard_epochs = eps;
                sketch = Some(sk);
            }
        } else {
            checkpoint::clear(&self.work_dir);
        }

        let max_rank_eff = if self.max_rank == 0 {
            super::DEFAULT_MAX_RANK
        } else {
            self.max_rank
        };
        // For dense streams the sketch never needs to be wider than n; a
        // sparse dictionary can still grow, so it stays unclamped there.
        let mut dense_cols: Option<usize> = None;
        let mut last_checkpoint = Instant::now();

        loop {
            let t0 = Instant::now();
            let Some(batch) = source.next_batch(self.batch_rows)? else { break };
            let full = batch.rows() == self.batch_rows;
            if matches!(batch, Batch::Dense(_)) {
                dense_cols = Some(batch.cols());
            }
            if sketch.is_none() {
                let clamp = |w: usize| match dense_cols {
                    Some(n) => w.min(n).max(1),
                    None => w.max(1),
                };
                let width = match self.rank {
                    Some(k) => clamp(k + self.oversample),
                    None => clamp(self.start_width.min(max_rank_eff + self.oversample)),
                };
                sketch = Some(SketchState::new(self.seed, batch.cols(), width));
            }
            let sk = sketch.as_mut().expect("sketch initialized above");
            let y = match &batch {
                Batch::Dense(a) => sk.absorb_dense(a, backend.as_ref())?,
                Batch::Sparse(a) => sk.absorb_sparse(a, backend.as_ref())?,
            };
            report.push("stream.absorb", t0.elapsed(), batch.rows() as u64, 0);
            // Per-batch absorb wall time (read + rotate + fold); quantiles
            // show whether ingest keeps up with the source.
            metrics.observe("stream_absorb_ms", t0.elapsed().as_secs_f64() * 1e3);

            let t0 = Instant::now();
            let idx = shard_epochs.len();
            let mut w = sy.open_writer(idx, y.cols())?;
            for i in 0..y.rows() {
                w.write_row(y.row(i))?;
            }
            w.finish()?;
            shard_epochs.push(sk.current_epoch() as u32);
            report.push("stream.shard_y", t0.elapsed(), y.rows() as u64, 0);

            metrics.set("stream_rows", sk.rows() as f64);
            metrics.add("stream_batches", 1.0);
            metrics.set("stream_width", sk.width() as f64);

            // Adaptive widening: only when rank isn't pinned, the batch was
            // full (more rows are plausible), and headroom remains. Never at
            // EOF — widening after the last row buys nothing.
            if self.rank.is_none() && full {
                let max_w = match dense_cols {
                    Some(n) => (max_rank_eff + self.oversample).min(n),
                    None => max_rank_eff + self.oversample,
                };
                if sk.width() < max_w {
                    let t0 = Instant::now();
                    let rel =
                        sk.residual(self.center, self.sigma_cutoff_rel, backend.as_ref())?;
                    metrics.set("stream_residual", rel);
                    // The gauge holds only the latest estimate; the
                    // histogram keeps the whole trajectory of the run.
                    metrics.observe("stream_residual_trajectory", rel);
                    report.push("stream.residual", t0.elapsed(), 0, 0);
                    if rel > self.tol {
                        let add = sk.width().min(max_w - sk.width());
                        let t0 = Instant::now();
                        sk.widen(add, self.sigma_cutoff_rel, backend.as_ref())?;
                        metrics.add("stream_widenings", 1.0);
                        metrics.set("stream_width", sk.width() as f64);
                        report.push("stream.widen", t0.elapsed(), add as u64, 0);
                    }
                }
            }
            if self.checkpoint && last_checkpoint.elapsed() >= self.checkpoint_interval {
                let t0 = Instant::now();
                checkpoint::save(&self.work_dir, sk, &shard_epochs)?;
                last_checkpoint = Instant::now();
                report.push("stream.checkpoint", t0.elapsed(), 0, 0);
            }
            if let Some(cb) = progress.as_mut() {
                cb(sk.rows(), sk.width());
            }
        }

        let sk = sketch
            .ok_or_else(|| Error::Other("stream ended before any rows arrived".into()))?;

        // The finish tail (recovery + shard rotation) runs after the last
        // batch callback; keep ticking so a supervisor heartbeat riding
        // the callback does not go stale over a long tail.
        if let Some(cb) = progress.as_mut() {
            cb(sk.rows(), sk.width());
        }
        let t0 = Instant::now();
        let rec = sk.finish(
            self.center,
            self.rank,
            self.tol,
            max_rank_eff,
            self.sigma_cutoff_rel,
            backend.as_ref(),
        )?;
        report.push("leader.recover", t0.elapsed(), sk.width() as u64, 0);
        metrics.set("stream_k", rec.k as f64);
        metrics.set("stream_residual", rec.residual);

        // Rotate the k'-wide Y shards into k-wide U shards:
        // u = y · rotations[epoch] - shifts[epoch].
        let t0 = Instant::now();
        let u_set = ShardSet::new(&self.work_dir, "U", InputFormat::Bin)?;
        let mut rotated_rows = 0u64;
        for (i, &ep) in shard_epochs.iter().enumerate() {
            let rot = &rec.rotations[ep as usize];
            let shift = &rec.shifts[ep as usize];
            let mut r = sy.open_reader(i)?;
            let mut w = u_set.open_writer(i, rec.k)?;
            let mut row = Vec::new();
            let mut u_row = vec![0.0; rec.k];
            while r.next_row(&mut row)? {
                if row.len() != rot.rows() {
                    return Err(Error::shape(format!(
                        "Y shard {i} row has {} cols, epoch {ep} rotation expects {}",
                        row.len(),
                        rot.rows()
                    )));
                }
                for (u, &s) in u_row.iter_mut().zip(shift.iter()) {
                    *u = -s;
                }
                for (p, &yv) in row.iter().enumerate() {
                    if yv == 0.0 {
                        continue;
                    }
                    for (u, &rv) in u_row.iter_mut().zip(rot.row(p)) {
                        *u += yv * rv;
                    }
                }
                w.write_row(&u_row)?;
                rotated_rows += 1;
            }
            w.finish()?;
            if let Some(cb) = progress.as_mut() {
                cb(sk.rows(), sk.width());
            }
        }
        report.push("stream.rotate_u", t0.elapsed(), rotated_rows, 0);
        if rotated_rows != sk.rows() {
            return Err(Error::Other(format!(
                "Y shards held {rotated_rows} rows, sketch absorbed {}",
                sk.rows()
            )));
        }

        sy.cleanup(shard_epochs.len());
        checkpoint::clear(&self.work_dir);

        let result = SvdResult {
            m: sk.rows() as usize,
            n: sk.cols(),
            k: rec.k,
            sigma: rec.sigma,
            v: Some(rec.v),
            v_shards: None,
            v_bands: 0,
            u_shards: u_set,
            shards: shard_epochs.len(),
            means: rec.means,
            report,
        };
        if let Some(dir) = &self.save_model {
            result.save_model(dir, Some(self.seed))?;
        }
        Ok(result)
    }
}
