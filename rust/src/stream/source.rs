//! Forward-only row sources: batch readers over any [`Read`] in the four
//! input framings (csv / binmat / libsvm / csr-stream).
//!
//! Unlike the seekable readers in [`crate::io`], nothing here seeks, stats
//! the file, or reads anything twice — a pipe, a socket, or stdin works.
//! Binary headers are parsed from the byte stream itself; the binmat row
//! count is treated as advisory (a piped writer may not have back-patched
//! it), rows are read until EOF and a torn trailing row is an error.
//! CSR framing is the exception: its indptr table travels *before* the
//! payloads, so the header row count is load-bearing — a CSR producer
//! writing into a pipe must emit an accurate header up front (`rows = 0`
//! is rejected; use libsvm / sparse-csv framing for open-ended sparse
//! streams). The count is still not trusted with memory: indptr is read
//! incrementally and a stream ending mid-table is a framing error, not a
//! huge allocation.
//!
//! Sparse text streams keep a *persistent column dictionary*: the width is
//! the running max column index + 1 across every batch seen so far (or the
//! pinned `--cols` width), so later batches can reference columns earlier
//! batches never touched.

use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::binmat::DType;
use crate::io::csv::parse_row_bytes;
use crate::io::sparse::{parse_libsvm_row, parse_sparse_csv_row};
use crate::linalg::{Matrix, SparseMatrix};
use std::io::{BufRead, BufReader, Read};

/// One absorbed batch of rows.
pub enum Batch {
    /// Dense rows (csv / binmat framing).
    Dense(Matrix),
    /// Sparse CSR rows (libsvm / sparse-csv / csr framing); `cols()` is the
    /// column dictionary width as of this batch.
    Sparse(SparseMatrix),
}

impl Batch {
    /// Rows in the batch.
    pub fn rows(&self) -> usize {
        match self {
            Batch::Dense(a) => a.rows(),
            Batch::Sparse(a) => a.rows(),
        }
    }

    /// Column count as of this batch.
    pub fn cols(&self) -> usize {
        match self {
            Batch::Dense(a) => a.cols(),
            Batch::Sparse(a) => a.cols(),
        }
    }
}

/// Per-format framing state.
enum Framing {
    /// `;`-separated dense text; width fixed by the first row.
    Csv,
    /// binmat: header parsed, then fixed-size rows until EOF.
    Bin { cols: usize, dtype: DType, row_buf: Vec<u8> },
    /// libsvm / sparse-csv text.
    SparseText(InputFormat),
    /// CSR: header + indptr parsed, then per-row payloads. `row_len` is
    /// per-row nonzero counts (v1) or payload byte lengths (v2) — the
    /// successive differences of the on-disk indptr either way.
    Csr { version: u32, row_len: Vec<u64>, next: usize },
}

/// A forward-only batch reader over any byte stream.
pub struct StreamSource {
    reader: BufReader<Box<dyn Read + Send>>,
    format: InputFormat,
    framing: Option<Framing>,
    /// Current column-dictionary width (running max for sparse text).
    cols: usize,
    /// Pinned width (`--cols`): indices at or past it are an error.
    cols_pin: usize,
    rows_read: u64,
    line_buf: Vec<u8>,
}

impl StreamSource {
    /// Open a path: `-` is stdin; anything else is `File::open`, which on
    /// a FIFO blocks until a writer appears — exactly the pipe semantics
    /// the daemon's stream jobs rely on.
    pub fn open(path: &str, format: InputFormat) -> Result<Self> {
        let reader: Box<dyn Read + Send> = if path == "-" {
            Box::new(std::io::stdin())
        } else {
            Box::new(std::fs::File::open(path).map_err(|e| {
                Error::Other(format!("cannot open stream input {path}: {e}"))
            })?)
        };
        Ok(Self::from_reader(reader, format))
    }

    /// Wrap an arbitrary byte stream.
    pub fn from_reader(reader: Box<dyn Read + Send>, format: InputFormat) -> Self {
        StreamSource {
            reader: BufReader::with_capacity(1 << 20, reader),
            format,
            framing: None,
            cols: 0,
            cols_pin: 0,
            rows_read: 0,
            line_buf: Vec::with_capacity(4096),
        }
    }

    /// Pin the column dictionary width (0 = derive from the stream).
    pub fn pin_cols(&mut self, n: usize) {
        self.cols_pin = n;
        if n > 0 {
            self.cols = self.cols.max(n);
        }
    }

    /// Rows handed out so far.
    pub fn rows_read(&self) -> u64 {
        self.rows_read
    }

    /// Current column-dictionary width (0 before the first row).
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Read and discard `n` rows (checkpoint-resume replay over a source
    /// that restarts from the beginning, e.g. a regular file).
    pub fn skip_rows(&mut self, n: u64) -> Result<()> {
        let mut skipped = 0u64;
        while skipped < n {
            let want = (n - skipped).min(4096) as usize;
            let Some(batch) = self.next_batch(want)? else {
                return Err(Error::Other(format!(
                    "stream ended after {skipped} rows while skipping {n} \
                     checkpointed rows — source shorter than the checkpoint"
                )));
            };
            skipped += batch.rows() as u64;
        }
        Ok(())
    }

    /// Read up to `max_rows` rows; `None` at a clean end of stream.
    pub fn next_batch(&mut self, max_rows: usize) -> Result<Option<Batch>> {
        debug_assert!(max_rows > 0);
        self.prime()?;
        let batch = match self.format {
            InputFormat::Csv | InputFormat::Bin => self.next_dense(max_rows)?,
            _ => self.next_sparse(max_rows)?,
        };
        if let Some(b) = &batch {
            self.rows_read += b.rows() as u64;
        }
        Ok(batch)
    }

    /// Parse the framing header on first use.
    fn prime(&mut self) -> Result<()> {
        if self.framing.is_some() {
            return Ok(());
        }
        let framing = match self.format {
            InputFormat::Csv => Framing::Csv,
            InputFormat::Libsvm | InputFormat::SparseCsv => Framing::SparseText(self.format),
            InputFormat::Bin => {
                let mut buf = [0u8; 32];
                self.reader.read_exact(&mut buf)?;
                if &buf[0..4] != crate::io::binmat::MAGIC {
                    return Err(Error::parse("stream: bad binmat magic"));
                }
                let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
                if version != crate::io::binmat::VERSION {
                    return Err(Error::parse(format!(
                        "stream: unsupported binmat version {version}"
                    )));
                }
                let cols = u64::from_le_bytes(buf[16..24].try_into().unwrap()) as usize;
                let dtype = match buf[24] {
                    1 => DType::F32,
                    2 => DType::F64,
                    other => return Err(Error::parse(format!("stream: bad dtype {other}"))),
                };
                if cols == 0 {
                    return Err(Error::parse("stream: binmat header has 0 cols"));
                }
                self.set_dense_cols(cols)?;
                // header `rows` is advisory on a pipe (a streaming writer
                // back-patches it at finish, which a pipe never sees) —
                // rows are read until EOF instead.
                Framing::Bin { cols, dtype, row_buf: vec![0u8; cols * dtype.size()] }
            }
            InputFormat::Csr => {
                let mut buf = [0u8; 32];
                self.reader.read_exact(&mut buf)?;
                if &buf[0..4] != crate::io::sparse::CSR_MAGIC {
                    return Err(Error::parse("stream: bad csr magic"));
                }
                let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
                if version != crate::io::sparse::CSR_VERSION
                    && version != crate::io::sparse::CSR_VERSION_V1
                {
                    return Err(Error::parse(format!(
                        "stream: unsupported csr version {version}"
                    )));
                }
                let rows = u64::from_le_bytes(buf[8..16].try_into().unwrap());
                let cols = u64::from_le_bytes(buf[16..24].try_into().unwrap()) as usize;
                // Unlike binmat, csr cannot treat the header count as
                // advisory: indptr travels before the payloads and is
                // sized by it. A placeholder header would silently frame
                // an empty stream, so demand an accurate one.
                if rows == 0 {
                    return Err(Error::parse(
                        "stream: csr header claims 0 rows — csr framing needs an \
                         accurate up-front row count (a piped producer cannot \
                         back-patch it; use libsvm or sparse-csv framing for \
                         open-ended sparse streams)",
                    ));
                }
                if self.cols_pin > 0 && cols > self.cols_pin {
                    return Err(Error::Config(format!(
                        "stream: csr header width {cols} exceeds the pinned --cols {}",
                        self.cols_pin
                    )));
                }
                self.cols = self.cols.max(cols);
                // indptr: (rows + 1) u64s, read sequentially. The claimed
                // count bounds the loop, never an up-front allocation — a
                // corrupt or hostile header hits EOF, not the allocator.
                let count = rows.saturating_add(1);
                let mut ip = [0u8; 8];
                let mut indptr: Vec<u64> = Vec::with_capacity(count.min(1 << 16) as usize);
                for i in 0..count {
                    self.reader.read_exact(&mut ip).map_err(|e| {
                        Error::parse(format!(
                            "stream: csr indptr truncated at entry {i} of {count} \
                             (header claims {rows} rows): {e}"
                        ))
                    })?;
                    let v = u64::from_le_bytes(ip);
                    if indptr.last().is_some_and(|&prev| v < prev) {
                        return Err(Error::parse(format!(
                            "stream: csr indptr decreases at entry {i} ({v} after {})",
                            indptr.last().unwrap()
                        )));
                    }
                    indptr.push(v);
                }
                let row_len = indptr.windows(2).map(|w| w[1] - w[0]).collect();
                Framing::Csr { version, row_len, next: 0 }
            }
        };
        self.framing = Some(framing);
        Ok(())
    }

    fn set_dense_cols(&mut self, cols: usize) -> Result<()> {
        if self.cols_pin > 0 && cols != self.cols_pin {
            return Err(Error::Config(format!(
                "stream: dense row width {cols} disagrees with the pinned --cols {}",
                self.cols_pin
            )));
        }
        self.cols = cols;
        Ok(())
    }

    fn next_dense(&mut self, max_rows: usize) -> Result<Option<Batch>> {
        let mut rows: Vec<Vec<f64>> = Vec::new();
        let mut row = Vec::new();
        while rows.len() < max_rows {
            match self.framing.as_mut().expect("primed") {
                Framing::Csv => {
                    self.line_buf.clear();
                    let n = self.reader.read_until(b'\n', &mut self.line_buf)?;
                    if n == 0 {
                        break;
                    }
                    if parse_row_bytes(&self.line_buf, &mut row)? == 0 {
                        continue; // blank line
                    }
                    if self.cols == 0 {
                        // Inline of set_dense_cols (the framing borrow is live).
                        if self.cols_pin > 0 && row.len() != self.cols_pin {
                            return Err(Error::Config(format!(
                                "stream: dense row width {} disagrees with the pinned --cols {}",
                                row.len(),
                                self.cols_pin
                            )));
                        }
                        self.cols = row.len();
                    } else if row.len() != self.cols {
                        return Err(Error::parse(format!(
                            "stream: ragged csv row {} ({} cols, expected {})",
                            self.rows_read + rows.len() as u64,
                            row.len(),
                            self.cols
                        )));
                    }
                    rows.push(row.clone());
                }
                Framing::Bin { cols, dtype, row_buf } => {
                    match read_full(&mut self.reader, row_buf)? {
                        0 => break, // clean EOF at a row boundary
                        n if n == row_buf.len() => {}
                        n => {
                            return Err(Error::parse(format!(
                                "stream: torn binmat row ({n} of {} bytes)",
                                row_buf.len()
                            )))
                        }
                    }
                    row.clear();
                    match dtype {
                        DType::F32 => {
                            for c in row_buf.chunks_exact(4) {
                                row.push(f32::from_le_bytes(c.try_into().unwrap()) as f64);
                            }
                        }
                        DType::F64 => {
                            for c in row_buf.chunks_exact(8) {
                                row.push(f64::from_le_bytes(c.try_into().unwrap()));
                            }
                        }
                    }
                    debug_assert_eq!(row.len(), *cols);
                    rows.push(row.clone());
                }
                _ => unreachable!("dense framing"),
            }
        }
        if rows.is_empty() {
            return Ok(None);
        }
        Ok(Some(Batch::Dense(Matrix::from_rows(&rows)?)))
    }

    fn next_sparse(&mut self, max_rows: usize) -> Result<Option<Batch>> {
        let mut parsed: Vec<(Vec<u32>, Vec<f64>)> = Vec::new();
        let mut indices = Vec::new();
        let mut values = Vec::new();
        while parsed.len() < max_rows {
            let got = match self.framing.as_mut().expect("primed") {
                Framing::SparseText(fmt) => {
                    self.line_buf.clear();
                    let n = self.reader.read_until(b'\n', &mut self.line_buf)?;
                    if n == 0 {
                        break;
                    }
                    let is_row = match fmt {
                        InputFormat::Libsvm => {
                            parse_libsvm_row(&self.line_buf, &mut indices, &mut values)?
                        }
                        _ => parse_sparse_csv_row(&self.line_buf, &mut indices, &mut values)?,
                    };
                    if !is_row {
                        continue; // blank / comment
                    }
                    true
                }
                Framing::Csr { version, row_len, next } => {
                    if *next >= row_len.len() {
                        break;
                    }
                    let len = row_len[*next] as usize;
                    *next += 1;
                    if *version == crate::io::sparse::CSR_VERSION_V1 {
                        // v1: `len` nonzeros as raw u32 indices + f64 values
                        indices.clear();
                        values.clear();
                        let mut b4 = [0u8; 4];
                        for _ in 0..len {
                            self.reader.read_exact(&mut b4)?;
                            indices.push(u32::from_le_bytes(b4));
                        }
                        let mut b8 = [0u8; 8];
                        for _ in 0..len {
                            self.reader.read_exact(&mut b8)?;
                            values.push(f64::from_le_bytes(b8));
                        }
                    } else {
                        // v2: `len` bytes of delta/varint row payload. Fill
                        // incrementally so a hostile byte count hits EOF,
                        // not the allocator (same discipline as indptr).
                        self.line_buf.clear();
                        let mut chunk = [0u8; 4096];
                        let mut remaining = len;
                        while remaining > 0 {
                            let take = remaining.min(chunk.len());
                            self.reader.read_exact(&mut chunk[..take])?;
                            self.line_buf.extend_from_slice(&chunk[..take]);
                            remaining -= take;
                        }
                        crate::io::sparse::decode_v2_row(
                            &self.line_buf,
                            self.cols as u64,
                            &mut indices,
                            &mut values,
                        )?;
                    }
                    true
                }
                _ => unreachable!("sparse framing"),
            };
            if got {
                if let Some(&max_idx) = indices.iter().max() {
                    let need = max_idx as usize + 1;
                    if self.cols_pin > 0 && need > self.cols_pin {
                        return Err(Error::Config(format!(
                            "stream: column index {max_idx} exceeds the pinned --cols {} \
                             dictionary",
                            self.cols_pin
                        )));
                    }
                    self.cols = self.cols.max(need);
                }
                parsed.push((indices.clone(), values.clone()));
            }
        }
        if parsed.is_empty() {
            return Ok(None);
        }
        let mut sm = SparseMatrix::with_cols(self.cols);
        for (idx, val) in &parsed {
            sm.push_row(idx, val)?;
        }
        Ok(Some(Batch::Sparse(sm)))
    }
}

/// Read as many bytes as possible into `buf`; returns the count (0 = EOF,
/// short = EOF mid-buffer).
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> Result<usize> {
    let mut filled = 0;
    while filled < buf.len() {
        let n = r.read(&mut buf[filled..])?;
        if n == 0 {
            break;
        }
        filled += n;
    }
    Ok(filled)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::binmat::write_matrix_bin;
    use crate::io::InputSpec;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("tallfat_test_stream_source");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    fn cursor(bytes: Vec<u8>) -> Box<dyn Read + Send> {
        Box::new(std::io::Cursor::new(bytes))
    }

    #[test]
    fn csv_batches_and_width() {
        let text = "1;2;3\n4;5;6\n\n7;8;9\n10;11;12\n";
        let mut s = StreamSource::from_reader(cursor(text.into()), InputFormat::Csv);
        let b1 = s.next_batch(3).unwrap().unwrap();
        assert_eq!((b1.rows(), b1.cols()), (3, 3));
        let b2 = s.next_batch(3).unwrap().unwrap();
        assert_eq!(b2.rows(), 1);
        assert!(s.next_batch(3).unwrap().is_none());
        assert_eq!(s.rows_read(), 4);
    }

    #[test]
    fn csv_ragged_rejected() {
        let mut s = StreamSource::from_reader(cursor("1;2\n3\n".into()), InputFormat::Csv);
        assert!(s.next_batch(10).is_err());
    }

    #[test]
    fn bin_reads_to_eof_despite_zero_row_header() {
        let m = Matrix::from_fn(5, 3, |i, j| (i * 3 + j) as f64);
        let path = tmp("hdr.bin");
        write_matrix_bin(&m, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Zero out the header row count — what a piped writer produces.
        bytes[8..16].copy_from_slice(&0u64.to_le_bytes());
        let mut s = StreamSource::from_reader(cursor(bytes), InputFormat::Bin);
        let b = s.next_batch(100).unwrap().unwrap();
        match b {
            Batch::Dense(got) => assert_eq!(got.max_abs_diff(&m), 0.0),
            _ => panic!("dense expected"),
        }
        assert!(s.next_batch(1).unwrap().is_none());
    }

    #[test]
    fn bin_torn_row_rejected() {
        let m = Matrix::from_fn(2, 4, |i, j| (i + j) as f64);
        let path = tmp("torn.bin");
        write_matrix_bin(&m, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 5);
        let mut s = StreamSource::from_reader(cursor(bytes), InputFormat::Bin);
        assert!(s.next_batch(10).is_err());
    }

    #[test]
    fn libsvm_dictionary_grows_across_batches() {
        let text = "1 1:1.0 2:2.0\n0 1:3.0\n# comment\n1 5:4.0\n";
        let mut s = StreamSource::from_reader(cursor(text.into()), InputFormat::Libsvm);
        let b1 = s.next_batch(2).unwrap().unwrap();
        assert_eq!(b1.cols(), 2); // max 1-based index 2 -> width 2
        let b2 = s.next_batch(2).unwrap().unwrap();
        assert_eq!(b2.cols(), 5); // index 5 widens the dictionary
        assert_eq!(s.cols(), 5);
    }

    #[test]
    fn pinned_cols_rejects_overflow_and_fixes_width() {
        let text = "0 1:1.0\n0 9:2.0\n";
        let mut s = StreamSource::from_reader(cursor(text.into()), InputFormat::Libsvm);
        s.pin_cols(4);
        let b = s.next_batch(1).unwrap().unwrap();
        assert_eq!(b.cols(), 4);
        assert!(s.next_batch(1).is_err()); // index 9 > pin 4
    }

    #[test]
    fn csr_stream_roundtrip() {
        let mut sm = SparseMatrix::with_cols(6);
        sm.push_row(&[0, 3], &[1.5, -2.0]).unwrap();
        sm.push_row(&[], &[]).unwrap();
        sm.push_row(&[5], &[4.0]).unwrap();
        let path = tmp("s.csr");
        crate::io::sparse::write_sparse_matrix(&sm, &path, InputFormat::Csr).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let mut s = StreamSource::from_reader(cursor(bytes), InputFormat::Csr);
        let b = s.next_batch(2).unwrap().unwrap();
        assert_eq!((b.rows(), b.cols()), (2, 6));
        let b2 = s.next_batch(2).unwrap().unwrap();
        assert_eq!(b2.rows(), 1);
        match b2 {
            Batch::Sparse(m) => {
                let (idx, val) = m.row(0);
                assert_eq!(idx, &[5]);
                assert_eq!(val, &[4.0]);
            }
            _ => panic!("sparse expected"),
        }
        assert!(s.next_batch(1).unwrap().is_none());
    }

    #[test]
    fn csr_zero_row_header_rejected() {
        let mut sm = SparseMatrix::with_cols(4);
        sm.push_row(&[1], &[2.0]).unwrap();
        let path = tmp("zero_rows.csr");
        crate::io::sparse::write_sparse_matrix(&sm, &path, InputFormat::Csr).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8..16].copy_from_slice(&0u64.to_le_bytes());
        let mut s = StreamSource::from_reader(cursor(bytes), InputFormat::Csr);
        let err = s.next_batch(1).unwrap_err().to_string();
        assert!(err.contains("0 rows"), "unexpected error: {err}");
    }

    #[test]
    fn csr_hostile_row_count_errors_instead_of_allocating() {
        let mut sm = SparseMatrix::with_cols(4);
        sm.push_row(&[0], &[1.0]).unwrap();
        let path = tmp("hostile.csr");
        crate::io::sparse::write_sparse_matrix(&sm, &path, InputFormat::Csr).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Claim u64::MAX rows: the reader must hit EOF mid-indptr, not
        // attempt a (rows + 1) * 8 byte allocation.
        bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        let mut s = StreamSource::from_reader(cursor(bytes), InputFormat::Csr);
        let err = s.next_batch(1).unwrap_err().to_string();
        assert!(err.contains("indptr truncated"), "unexpected error: {err}");
    }

    #[test]
    fn csr_decreasing_indptr_rejected() {
        let mut sm = SparseMatrix::with_cols(4);
        sm.push_row(&[0, 1], &[1.0, 2.0]).unwrap();
        sm.push_row(&[2], &[3.0]).unwrap();
        let path = tmp("decreasing.csr");
        crate::io::sparse::write_sparse_matrix(&sm, &path, InputFormat::Csr).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // indptr entries start at byte 32; inflate the middle (v2 byte
        // offset) far past any real payload so the next entry decreases.
        bytes[40..48].copy_from_slice(&(1u64 << 40).to_le_bytes());
        let mut s = StreamSource::from_reader(cursor(bytes), InputFormat::Csr);
        let err = s.next_batch(1).unwrap_err().to_string();
        assert!(err.contains("indptr decreases"), "unexpected error: {err}");
    }

    #[test]
    fn skip_rows_replays_forward() {
        let text: String = (0..20).map(|i| format!("{i};{i}\n")).collect();
        let mut s = StreamSource::from_reader(cursor(text.into()), InputFormat::Csv);
        s.skip_rows(15).unwrap();
        let b = s.next_batch(100).unwrap().unwrap();
        assert_eq!(b.rows(), 5);
        match b {
            Batch::Dense(m) => assert_eq!(m.get(0, 0), 15.0),
            _ => panic!(),
        }
        // Skipping past the end errors.
        let mut s2 = StreamSource::from_reader(cursor("1;1\n".into()), InputFormat::Csv);
        assert!(s2.skip_rows(5).is_err());
    }

    #[test]
    fn open_rejects_missing_and_reads_files() {
        assert!(StreamSource::open("/nonexistent/x.csv", InputFormat::Csv).is_err());
        let path = tmp("open.csv");
        std::fs::write(&path, "1;2\n3;4\n").unwrap();
        let spec = InputSpec::auto(path.clone());
        let mut s = StreamSource::open(&path, spec.format).unwrap();
        assert_eq!(s.next_batch(10).unwrap().unwrap().rows(), 2);
    }
}
