//! The one-pass HMT sketch accumulator and its leader-side recovery.
//!
//! [`SketchState`] is everything the streaming route retains about the rows
//! it has seen: `G = YᵀY` (width x width), `W = AᵀY` (n x width), the
//! Frobenius mass, and per-epoch row statistics for centering — all sized
//! by the sketch width k', never by m. The `Y` row blocks themselves go to
//! disk shards owned by the builder; this module only tells it what to
//! write.
//!
//! ## Epochs
//!
//! Every widening of Ω closes an *epoch*. Rows absorbed during epoch `e`
//! had their `Y` rows written at that epoch's width `w_e`; at widening time
//! the closed epoch records the extension map `T_e` (composed across later
//! widenings into `map_e : w_e x width`) that lifts those on-disk rows to
//! the current width: `y_lifted = y_raw · map_e`. The same map keeps the
//! per-epoch centering statistics consistent, because the *effective*
//! sketch each epoch's rows saw is `Ω[:, ..w_e] · map_e` — different per
//! epoch, which is why the centering corrections below are per-epoch sums
//! rather than one global rank-1 update.
//!
//! ## Widening without the rows
//!
//! With `M = V_y Σ_y⁻¹` from `eigh(G)` (so `U0 = Y M` has orthonormal
//! columns in exact arithmetic), the best available reconstruction of the
//! unseen products `A Ω_add` is `U0 U0ᵀ A Ω_add = Y · (M Mᵀ Wᵀ Ω_add)`.
//! Hence widening is the linear map `T = [I | M Mᵀ Wᵀ Ω_add]` applied on
//! the right of `Y`, which updates every accumulator in closed form:
//! `G ← TᵀGT`, `W ← WT`, `s_y ← s_y T`. Rows arriving after the widening
//! project against the wider Ω exactly.

use crate::backend::Backend;
use crate::error::{Error, Result};
use crate::linalg::{matmul, matmul_tn, Matrix, SparseMatrix};
use crate::rng::VirtualMatrix;
use crate::svd::pipeline::{guarded_inverse, COMPLETION_CUTOFF_REL};

/// Row statistics of one sketch-width epoch.
#[derive(Clone)]
pub(crate) struct Epoch {
    /// Sketch width when the epoch opened — the column count of its on-disk
    /// `Y` shards.
    pub(crate) width: usize,
    /// Rows absorbed during the epoch.
    pub(crate) rows: u64,
    /// Per-column input sums `Σ_i a_i` over the epoch's rows (length n,
    /// grown with the column dictionary).
    pub(crate) colsums: Vec<f64>,
    /// Sketch-row sum `Σ_i y_i` over the epoch's rows, kept mapped to the
    /// *current* width (transformed by `T` at each widening).
    pub(crate) s_y: Vec<f64>,
    /// Composed extension map `w_e x width` for a closed epoch; `None` for
    /// the current epoch (identity).
    pub(crate) map: Option<Matrix>,
}

/// The k'-sized one-pass sketch of everything streamed so far.
pub struct SketchState {
    pub(crate) seed: u64,
    /// Column count seen so far (grows with a sparse column dictionary).
    pub(crate) n: usize,
    /// Current sketch width k'.
    pub(crate) width: usize,
    /// `G = YᵀY`, width x width.
    pub(crate) g: Matrix,
    /// `W = AᵀY`, n x width.
    pub(crate) w: Matrix,
    /// `‖A‖_F²` over all absorbed rows.
    pub(crate) fro2: f64,
    /// Total rows absorbed.
    pub(crate) rows: u64,
    pub(crate) epochs: Vec<Epoch>,
    /// Dense Ω cache for the current `(n, width)`; rebuilt after any growth.
    omega: Option<Matrix>,
}

/// Everything the builder needs to emit factors from the sketch.
pub struct Recovery {
    /// Chosen rank.
    pub k: usize,
    /// Top-k singular values, descending.
    pub sigma: Vec<f64>,
    /// Right factor `V`, n x k.
    pub v: Matrix,
    /// Per-epoch rotation `w_e x k`: a raw on-disk `Y` row becomes a `U`
    /// row via `u = y · rotations[e] - shifts[e]`.
    pub rotations: Vec<Matrix>,
    /// Per-epoch centering shift (length k; zeros when uncentered).
    pub shifts: Vec<Vec<f64>>,
    /// Column means when centering, else `None`.
    pub means: Option<Vec<f64>>,
    /// A posteriori relative residual estimate at the chosen rank.
    pub residual: f64,
}

impl SketchState {
    /// Fresh sketch at `width` over (initially) `n` columns.
    pub fn new(seed: u64, n: usize, width: usize) -> Self {
        SketchState {
            seed,
            n,
            width,
            g: Matrix::zeros(width, width),
            w: Matrix::zeros(n, width),
            fro2: 0.0,
            rows: 0,
            epochs: vec![Epoch {
                width,
                rows: 0,
                colsums: vec![0.0; n],
                s_y: vec![0.0; width],
                map: None,
            }],
            omega: None,
        }
    }

    /// Rebuild from checkpointed parts.
    pub(crate) fn from_parts(
        seed: u64,
        fro2: f64,
        rows: u64,
        g: Matrix,
        w: Matrix,
        epochs: Vec<Epoch>,
    ) -> Self {
        let width = g.rows();
        let n = w.rows();
        SketchState { seed, n, width, g, w, fro2, rows, epochs, omega: None }
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn cols(&self) -> usize {
        self.n
    }

    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// Index of the epoch currently absorbing rows.
    pub fn current_epoch(&self) -> usize {
        self.epochs.len() - 1
    }

    /// Grow the column dictionary to `n_new` (sparse streams discover
    /// columns as they go). `W` gains zero rows; Ω, being a pure function
    /// of `(i, j)`, simply has more rows used.
    pub fn ensure_cols(&mut self, n_new: usize) {
        if n_new <= self.n {
            return;
        }
        let mut w = Matrix::zeros(n_new, self.width);
        for i in 0..self.n {
            w.row_mut(i).copy_from_slice(self.w.row(i));
        }
        self.w = w;
        for ep in &mut self.epochs {
            ep.colsums.resize(n_new, 0.0);
        }
        self.n = n_new;
        self.omega = None;
    }

    /// Absorb a dense row batch; returns the `Y` block (batch x width) for
    /// the builder to shard.
    pub fn absorb_dense(&mut self, a: &Matrix, backend: &dyn Backend) -> Result<Matrix> {
        if a.cols() != self.n {
            return Err(Error::shape(format!(
                "stream batch has {} cols, sketch has {}",
                a.cols(),
                self.n
            )));
        }
        if self.omega.is_none() {
            self.omega =
                Some(VirtualMatrix::standard(self.seed, self.n, self.width).materialize());
        }
        let omega = self.omega.as_ref().expect("omega cache just filled");
        let (y, gb) = backend.project_gram_block(a, omega)?;
        self.g.add_assign(&gb)?;
        let wb = backend.tmul_block(a, &y)?;
        self.w.add_assign(&wb)?;
        self.fro2 += a.data().iter().map(|v| v * v).sum::<f64>();
        let ep = self.epochs.last_mut().expect("sketch has an open epoch");
        ep.rows += a.rows() as u64;
        for i in 0..a.rows() {
            for (c, &v) in ep.colsums.iter_mut().zip(a.row(i)) {
                *c += v;
            }
            for (s, &v) in ep.s_y.iter_mut().zip(y.row(i)) {
                *s += v;
            }
        }
        self.rows += a.rows() as u64;
        Ok(y)
    }

    /// Absorb a sparse (CSR) row batch — `O(nnz · width)`, Ω sampled
    /// per-element, never materialized against the full dictionary.
    pub fn absorb_sparse(&mut self, a: &SparseMatrix, backend: &dyn Backend) -> Result<Matrix> {
        self.ensure_cols(a.cols());
        let vm = VirtualMatrix::standard(self.seed, self.n, self.width);
        let mut y = Matrix::zeros(a.rows(), self.width);
        for i in 0..a.rows() {
            let (idx, val) = a.row(i);
            let out = y.row_mut(i);
            for (&c, &v) in idx.iter().zip(val) {
                for (j, o) in out.iter_mut().enumerate() {
                    *o += v * vm.element(c as usize, j);
                }
            }
        }
        let gb = backend.gram_block(&y)?;
        self.g.add_assign(&gb)?;
        let wb = backend.tmul_block_sparse(a, &y)?;
        for i in 0..wb.rows() {
            for (wv, &bv) in self.w.row_mut(i).iter_mut().zip(wb.row(i)) {
                *wv += bv;
            }
        }
        let ep = self.epochs.last_mut().expect("sketch has an open epoch");
        ep.rows += a.rows() as u64;
        for i in 0..a.rows() {
            let (idx, val) = a.row(i);
            for (&c, &v) in idx.iter().zip(val) {
                ep.colsums[c as usize] += v;
                self.fro2 += v * v;
            }
            for (s, &v) in ep.s_y.iter_mut().zip(y.row(i)) {
                *s += v;
            }
        }
        self.rows += a.rows() as u64;
        Ok(y)
    }

    /// Widen the sketch by `add` columns without revisiting any row:
    /// already-absorbed rows contribute to the new columns through the
    /// captured basis (`T = [I | M Mᵀ Wᵀ Ω_add]`), the current epoch closes
    /// with `map = T`, and a fresh epoch opens at the new width.
    pub fn widen(
        &mut self,
        add: usize,
        sigma_cutoff_rel: f64,
        backend: &dyn Backend,
    ) -> Result<()> {
        if add == 0 {
            return Ok(());
        }
        let w0 = self.width;
        let vm = VirtualMatrix::standard(self.seed, self.n, w0 + add);
        let omega_add = Matrix::from_fn(self.n, add, |i, j| vm.element(i, w0 + j));
        let m_mat = self.basis_map(&self.g, sigma_cutoff_rel, backend)?;
        let wto = matmul_tn(&self.w, &omega_add)?; // Wᵀ Ω_add : w0 x add
        let e = matmul_tn(&m_mat, &wto)?; // Mᵀ Wᵀ Ω_add : w0 x add
        let me = matmul(&m_mat, &e)?; // M Mᵀ Wᵀ Ω_add : w0 x add
        let t = Matrix::from_fn(w0, w0 + add, |i, j| {
            if j < w0 {
                if i == j {
                    1.0
                } else {
                    0.0
                }
            } else {
                me.get(i, j - w0)
            }
        });
        let gt = matmul(&self.g, &t)?;
        self.g = matmul_tn(&t, &gt)?; // Tᵀ G T
        self.w = matmul(&self.w, &t)?; // W T
        for ep in &mut self.epochs {
            ep.s_y = vecmat(&ep.s_y, &t)?;
            if let Some(map) = &ep.map {
                ep.map = Some(matmul(map, &t)?);
            }
        }
        // Close the current epoch with the bare extension map and open the
        // next one at the new width.
        self.epochs.last_mut().expect("open epoch").map = Some(t);
        self.epochs.push(Epoch {
            width: w0 + add,
            rows: 0,
            colsums: vec![0.0; self.n],
            s_y: vec![0.0; w0 + add],
            map: None,
        });
        self.width = w0 + add;
        self.omega = None;
        Ok(())
    }

    /// `M = V_y Σ_y⁻¹` from `eigh(g)` — the same basis map as the
    /// multi-pass sketch stage.
    fn basis_map(
        &self,
        g: &Matrix,
        sigma_cutoff_rel: f64,
        backend: &dyn Backend,
    ) -> Result<Matrix> {
        let (w_eig, v_y) = backend.eigh(g)?;
        let sig_y: Vec<f64> = w_eig.iter().map(|&w| w.max(0.0).sqrt()).collect();
        let inv_y = guarded_inverse(&sig_y, sigma_cutoff_rel);
        v_y.scale_cols(&inv_y)
    }

    /// Centering-corrected `(G_c, W_c, ‖A_c‖_F², μ, c_e per epoch)`.
    ///
    /// Epoch `e`'s rows effectively saw the sketch `Φ_e = Ω[:, ..w_e] map_e`,
    /// so their centered sketch rows are `y_i - c_e` with
    /// `c_e = (Ωᵀμ)[..w_e] · map_e`. Expanding `Σ (y - c_e)ᵀ(y - c_e)` and
    /// `Σ (a - μ)ᵀ(y - c_e)` gives the closed-form corrections below —
    /// exact, no extra pass.
    #[allow(clippy::type_complexity)]
    fn corrected(
        &self,
        center: bool,
    ) -> Result<(Matrix, Matrix, f64, Vec<f64>, Vec<Vec<f64>>)> {
        if !center || self.rows == 0 {
            let zeros: Vec<Vec<f64>> =
                self.epochs.iter().map(|_| vec![0.0; self.width]).collect();
            return Ok((self.g.clone(), self.w.clone(), self.fro2, Vec::new(), zeros));
        }
        let m = self.rows as f64;
        let mut mu = vec![0.0; self.n];
        for ep in &self.epochs {
            for (s, &c) in mu.iter_mut().zip(&ep.colsums) {
                *s += c;
            }
        }
        for v in &mut mu {
            *v /= m;
        }
        // Ωᵀμ over the full current width, then per-epoch projection.
        let vm = VirtualMatrix::standard(self.seed, self.n, self.width);
        let mut ymu = vec![0.0; self.width];
        vm.project_row(&mu, &mut ymu);
        let mut c_epochs = Vec::with_capacity(self.epochs.len());
        for ep in &self.epochs {
            let c = match &ep.map {
                Some(map) => vecmat(&ymu[..ep.width], map)?,
                None => ymu.clone(),
            };
            c_epochs.push(c);
        }

        let mut g_c = self.g.clone();
        let mut w_c = self.w.clone();
        let mut s_y_total = vec![0.0; self.width];
        for (ep, c) in self.epochs.iter().zip(&c_epochs) {
            let me = ep.rows as f64;
            // G_c -= s_yᵀ⊗c + cᵀ⊗s_y - m_e·cᵀ⊗c
            for a in 0..self.width {
                let row = g_c.row_mut(a);
                for (b, gv) in row.iter_mut().enumerate() {
                    *gv -= ep.s_y[a] * c[b] + c[a] * ep.s_y[b] - me * c[a] * c[b];
                }
            }
            // W_c -= colsums_eᵀ⊗c
            for p in 0..self.n {
                let cs = ep.colsums[p];
                if cs == 0.0 {
                    continue;
                }
                for (wv, &cv) in w_c.row_mut(p).iter_mut().zip(c.iter()) {
                    *wv -= cs * cv;
                }
            }
            for (t, (&s, &cv)) in s_y_total.iter_mut().zip(ep.s_y.iter().zip(c.iter())) {
                *t += s - me * cv;
            }
        }
        // W_c -= μᵀ ⊗ (s_y_total - Σ m_e c_e)  [folded into s_y_total above]
        for p in 0..self.n {
            let mv = mu[p];
            if mv == 0.0 {
                continue;
            }
            for (wv, &sv) in w_c.row_mut(p).iter_mut().zip(s_y_total.iter()) {
                *wv -= mv * sv;
            }
        }
        let mu2: f64 = mu.iter().map(|v| v * v).sum();
        let fro2_c = (self.fro2 - m * mu2).max(0.0);
        Ok((g_c, w_c, fro2_c, mu, c_epochs))
    }

    /// A posteriori relative residual estimate
    /// `‖A - U0U0ᵀA‖_F / ‖A‖_F = sqrt(1 - ‖W M‖_F² / ‖A‖_F²)` — exact when
    /// `U0 = Y M` has orthonormal columns. Cheap: one small eigh plus an
    /// `n x width` product.
    pub fn residual(
        &self,
        center: bool,
        sigma_cutoff_rel: f64,
        backend: &dyn Backend,
    ) -> Result<f64> {
        let (g_c, w_c, fro2_c, _, _) = self.corrected(center)?;
        if fro2_c <= 0.0 {
            return Ok(0.0);
        }
        let m_mat = self.basis_map(&g_c, sigma_cutoff_rel, backend)?;
        let wp = matmul(&w_c, &m_mat)?;
        let captured = wp.fro_norm().powi(2);
        Ok(((fro2_c - captured).max(0.0) / fro2_c).sqrt())
    }

    /// Recover the factorization from the sketch — the same leader math as
    /// the multi-pass route's completion, with `AᵀU0` taken from `W M`
    /// instead of a second pass.
    ///
    /// `rank_pin = Some(k)` fixes the output rank (multi-pass parity mode);
    /// otherwise the smallest rank whose σ-tail estimate meets `tol` is
    /// chosen, capped at `max_rank`.
    pub fn finish(
        &self,
        center: bool,
        rank_pin: Option<usize>,
        tol: f64,
        max_rank: usize,
        sigma_cutoff_rel: f64,
        backend: &dyn Backend,
    ) -> Result<Recovery> {
        if self.rows == 0 {
            return Err(Error::Other("stream ended before any rows arrived".into()));
        }
        let (g_c, w_c, fro2_c, mu, c_epochs) = self.corrected(center)?;
        let m_mat = self.basis_map(&g_c, sigma_cutoff_rel, backend)?;
        let wp = matmul(&w_c, &m_mat)?; // ≡ Aᵀ U0, n x width
        let gw = backend.gram_block(&wp)?;
        let (w2, p) = backend.eigh(&gw)?;
        let sigma_full: Vec<f64> = w2.iter().map(|&w| w.max(0.0).sqrt()).collect();

        let energy = fro2_c.max(1e-300);
        let k = match rank_pin {
            Some(k) => k.min(self.width).max(1),
            None => {
                let nonzero = sigma_full.iter().filter(|&&s| s > 0.0).count().max(1);
                let cap = self.width.min(nonzero).min(if max_rank == 0 {
                    usize::MAX
                } else {
                    max_rank
                });
                let mut tail = energy;
                let mut chosen = cap;
                for (i, &s) in sigma_full.iter().take(cap).enumerate() {
                    tail = (tail - s * s).max(0.0);
                    if (tail / energy).sqrt() <= tol {
                        chosen = i + 1;
                        break;
                    }
                }
                chosen
            }
        };
        let sigma: Vec<f64> = sigma_full[..k].to_vec();
        let captured: f64 = sigma.iter().map(|s| s * s).sum();
        let residual = ((energy - captured).max(0.0) / energy).sqrt();

        let p_k = p.slice_cols(0, k);
        let inv_s = guarded_inverse(&sigma, COMPLETION_CUTOFF_REL);
        let v = matmul(&wp, &p_k)?.scale_cols(&inv_s)?;
        let mp = matmul(&m_mat, &p_k)?; // width x k: y_lifted -> u
        let mut rotations = Vec::with_capacity(self.epochs.len());
        let mut shifts = Vec::with_capacity(self.epochs.len());
        for (ep, c) in self.epochs.iter().zip(&c_epochs) {
            rotations.push(match &ep.map {
                Some(map) => matmul(map, &mp)?,
                None => mp.clone(),
            });
            shifts.push(vecmat(c, &mp)?);
        }
        Ok(Recovery {
            k,
            sigma,
            v,
            rotations,
            shifts,
            means: if center { Some(mu) } else { None },
            residual,
        })
    }
}

/// Row-vector times matrix: `x · A` for `x` of length `A.rows()`.
fn vecmat(x: &[f64], a: &Matrix) -> Result<Vec<f64>> {
    if x.len() != a.rows() {
        return Err(Error::shape(format!(
            "vecmat: len {} vs {} rows",
            x.len(),
            a.rows()
        )));
    }
    let mut out = vec![0.0; a.cols()];
    for (i, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        for (o, &av) in out.iter_mut().zip(a.row(i)) {
            *o += xv * av;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::io::dataset::{gen_exact, Spectrum};

    fn backend() -> NativeBackend {
        NativeBackend::new()
    }

    fn rank_r(m: usize, n: usize, r: usize) -> Matrix {
        let (a, _) =
            gen_exact(m, n, r, Spectrum::Geometric { scale: 1.0, decay: 0.5 }, 0.0, 42).unwrap();
        a
    }

    /// Reference: project the full matrix against the same virtual Ω.
    fn direct_sketch(a: &Matrix, seed: u64, width: usize) -> (Matrix, Matrix, Matrix) {
        let vm = VirtualMatrix::standard(seed, a.cols(), width);
        let omega = vm.materialize();
        let y = matmul(a, &omega).unwrap();
        let g = matmul_tn(&y, &y).unwrap();
        let w = matmul_tn(a, &y).unwrap();
        (y, g, w)
    }

    #[test]
    fn accumulators_match_direct_projection() {
        let a = rank_r(60, 24, 6);
        let be = backend();
        let mut sk = SketchState::new(7, 24, 10);
        for r0 in (0..60).step_by(17) {
            let r1 = (r0 + 17).min(60);
            sk.absorb_dense(&a.slice_rows(r0, r1), &be).unwrap();
        }
        let (_, g, w) = direct_sketch(&a, 7, 10);
        assert!(sk.g.max_abs_diff(&g) < 1e-9, "G mismatch");
        assert!(sk.w.max_abs_diff(&w) < 1e-9, "W mismatch");
        assert!((sk.fro2 - a.fro_norm().powi(2)).abs() < 1e-9);
        assert_eq!(sk.rows(), 60);
    }

    #[test]
    fn sparse_absorb_matches_dense() {
        let a = rank_r(40, 16, 4);
        let be = backend();
        let sp = SparseMatrix::from_dense(&a, 0.0).unwrap();
        let mut dense = SketchState::new(3, 16, 8);
        dense.absorb_dense(&a, &be).unwrap();
        let mut sparse = SketchState::new(3, 0, 8);
        sparse.absorb_sparse(&sp, &be).unwrap();
        assert!(sparse.g.max_abs_diff(&dense.g) < 1e-9);
        assert!(sparse.w.max_abs_diff(&dense.w) < 1e-9);
        assert!((sparse.fro2 - dense.fro2).abs() < 1e-9);
    }

    #[test]
    fn widen_on_exactly_captured_rows_matches_full_width() {
        // Rank-4 rows sketched at width 8 are captured exactly, so the
        // widening reconstruction Y·T equals the true A·Ω at width 14 and
        // every accumulator must match the direct wide sketch.
        let a = rank_r(50, 20, 4);
        let be = backend();
        let mut sk = SketchState::new(11, 20, 8);
        sk.absorb_dense(&a, &be).unwrap();
        sk.widen(6, 1e-7, &be).unwrap();
        let (_, g, w) = direct_sketch(&a, 11, 14);
        assert!(sk.g.max_abs_diff(&g) < 1e-6, "G diff {}", sk.g.max_abs_diff(&g));
        assert!(sk.w.max_abs_diff(&w) < 1e-6, "W diff {}", sk.w.max_abs_diff(&w));
        assert_eq!(sk.epochs.len(), 2);
        assert_eq!(sk.epochs[0].width, 8);
        // The closed epoch's map lifts its stats to the new width.
        assert_eq!(sk.epochs[0].map.as_ref().unwrap().shape(), (8, 14));
    }

    #[test]
    fn residual_drops_as_width_grows() {
        let a = rank_r(80, 30, 12);
        let be = backend();
        let mut narrow = SketchState::new(5, 30, 4);
        narrow.absorb_dense(&a, &be).unwrap();
        let r_narrow = narrow.residual(false, 1e-7, &be).unwrap();
        let mut wide = SketchState::new(5, 30, 20);
        wide.absorb_dense(&a, &be).unwrap();
        let r_wide = wide.residual(false, 1e-7, &be).unwrap();
        assert!(
            r_wide < r_narrow,
            "residual should shrink with width: {r_narrow} -> {r_wide}"
        );
        // Width >= rank captures a rank-12 matrix (nearly) completely.
        assert!(r_wide < 1e-6, "r_wide = {r_wide}");
    }

    #[test]
    fn finish_recovers_known_factors() {
        let (a, sigma_true) =
            gen_exact(70, 25, 5, Spectrum::Geometric { scale: 1.0, decay: 0.6 }, 0.0, 9).unwrap();
        let be = backend();
        let mut sk = SketchState::new(2, 25, 12);
        let y = sk.absorb_dense(&a, &be).unwrap();
        let rec = sk.finish(false, Some(5), 1e-3, 0, 1e-7, &be).unwrap();
        assert_eq!(rec.k, 5);
        for (got, want) in rec.sigma.iter().zip(&sigma_true) {
            assert!((got - want).abs() < 1e-6 * want.max(1.0), "{got} vs {want}");
        }
        // U from the rotation, then check A ≈ U Σ Vᵀ.
        let u = matmul(&y, &rec.rotations[0]).unwrap();
        let us = u.scale_cols(&rec.sigma).unwrap();
        let approx = matmul(&us, &rec.v.t()).unwrap();
        assert!(approx.max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn centered_sketch_matches_precentered_input() {
        let a = rank_r(45, 18, 6);
        let be = backend();
        // Shift every column by a constant so centering has work to do.
        let shifted = Matrix::from_fn(45, 18, |i, j| a.get(i, j) + (j as f64) * 3.0);
        let mut mu = vec![0.0; 18];
        for i in 0..45 {
            for (m, &v) in mu.iter_mut().zip(shifted.row(i)) {
                *m += v;
            }
        }
        for m in &mut mu {
            *m /= 45.0;
        }
        let centered =
            Matrix::from_fn(45, 18, |i, j| shifted.get(i, j) - mu[j]);

        let mut sk = SketchState::new(13, 18, 10);
        sk.absorb_dense(&shifted.slice_rows(0, 20), &be).unwrap();
        sk.absorb_dense(&shifted.slice_rows(20, 45), &be).unwrap();
        let (g_c, w_c, fro2_c, mu_got, _) = sk.corrected(true).unwrap();

        let (_, g_ref, w_ref) = direct_sketch(&centered, 13, 10);
        assert!(g_c.max_abs_diff(&g_ref) < 1e-8, "diff {}", g_c.max_abs_diff(&g_ref));
        assert!(w_c.max_abs_diff(&w_ref) < 1e-8);
        assert!((fro2_c - centered.fro_norm().powi(2)).abs() < 1e-8);
        for (got, want) in mu_got.iter().zip(&mu) {
            assert!((got - want).abs() < 1e-12);
        }
    }

    #[test]
    fn centered_corrections_stay_exact_across_widening() {
        let a = rank_r(60, 22, 5);
        let be = backend();
        let shifted = Matrix::from_fn(60, 22, |i, j| a.get(i, j) + (j as f64) - 2.0);
        let mut sk = SketchState::new(21, 22, 9);
        sk.absorb_dense(&shifted.slice_rows(0, 30), &be).unwrap();
        sk.widen(5, 1e-7, &be).unwrap();
        sk.absorb_dense(&shifted.slice_rows(30, 60), &be).unwrap();
        // All corrections are per-epoch; the identity to check is the
        // finish-time reconstruction error staying at the rank-5+1 level
        // (centering adds at most rank 1).
        let rec = sk.finish(true, Some(6), 1e-3, 0, 1e-7, &be).unwrap();
        assert_eq!(rec.means.as_ref().unwrap().len(), 22);
        assert_eq!(rec.rotations.len(), 2);
        assert_eq!(rec.rotations[0].shape(), (9, 6));
        assert_eq!(rec.rotations[1].shape(), (14, 6));
        assert!(rec.residual < 1e-5, "residual {}", rec.residual);
    }

    #[test]
    fn ensure_cols_grows_dictionary() {
        let be = backend();
        let mut sk = SketchState::new(1, 0, 6);
        let mut b1 = SparseMatrix::with_cols(3);
        b1.push_row(&[0, 2], &[1.0, 2.0]).unwrap();
        sk.absorb_sparse(&b1, &be).unwrap();
        assert_eq!(sk.cols(), 3);
        let mut b2 = SparseMatrix::with_cols(7);
        b2.push_row(&[6], &[5.0]).unwrap();
        sk.absorb_sparse(&b2, &be).unwrap();
        assert_eq!(sk.cols(), 7);
        assert_eq!(sk.w.shape(), (7, 6));
        assert_eq!(sk.epochs[0].colsums.len(), 7);
        // The W row for the late column holds its contribution.
        assert!(sk.w.row(6).iter().any(|&v| v != 0.0));
    }
}
