//! The randomized rank-k SVD driver — the paper's pipeline end to end.
//!
//! ```text
//! pass 1  Y = A Ω           fused project+gram → Y shards + G = YᵀY   (over A)
//! leader  G = V_y Σ_y² V_yᵀ  k' x k' Jacobi eigensolve; M = V_y Σ_y⁻¹
//! pass 2  U0 = Y M           orthonormal basis rows → U0 shards
//!         W  = Aᵀ U0         commutative partial, reduced              (over A)
//! leader  WᵀW = P S² Pᵀ      second small eigensolve
//!         σ = S, V = W P S⁻¹
//! pass 3  U = U0 P           shard rotation                            (over U0)
//! ```
//!
//! Why the second eigensolve: σ(Y) carries the sketch's JL distortion; the
//! `W = AᵀU0` completion recovers A's own singular values exactly when
//! `rank(A) ≤ k'` (Halko et al. §5; still only `k' x k'` leader math, which
//! is the paper's design goal). With `power_iters > 0` the sketch is
//! re-orthonormalized and passed through A again (subspace iteration) for
//! slow-decaying spectra.
//!
//! The small-n route (`exact_gram`) skips the sketch entirely: `G = AᵀA`
//! eigensolved directly (paper §2.0.1), `U = A V Σ⁻¹` streamed.

pub mod pipeline;
pub mod result;
pub mod validate;

pub use pipeline::{gram_svd_file, randomized_svd_file, SvdOptions};
pub use result::SvdResult;
