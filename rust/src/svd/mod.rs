//! The randomized rank-k SVD — the paper's pipeline end to end, behind one
//! builder-style API over a pluggable execution substrate.
//!
//! ```text
//! pass 0  mu = colmeans(A)    optional PCA centering pre-pass            (over A)
//! pass 1  Y = A Ω             fused project+gram → Y shards + G = YᵀY    (over A)
//! leader  G = V_y Σ_y² V_yᵀ   k' x k' Jacobi eigensolve; M = V_y Σ_y⁻¹
//! pass 2  U0 = Y M            orthonormal basis rows → U0 shards
//!         W  = Aᵀ U0          commutative partial, reduced               (over A)
//! leader  WᵀW = P S² Pᵀ       second small eigensolve
//!         σ = S, V = W P S⁻¹
//! pass 3  U = U0 P            shard rotation                             (over U0)
//! ```
//!
//! Why the second eigensolve: σ(Y) carries the sketch's JL distortion; the
//! `W = AᵀU0` completion recovers A's own singular values exactly when
//! `rank(A) ≤ k'` (Halko et al. §5; still only `k' x k'` leader math, which
//! is the paper's design goal). With `power_iters > 0` the sketch is
//! re-orthonormalized and passed through A again (subspace iteration) for
//! slow-decaying spectra.
//!
//! The small-n route (`exact_gram`) skips the sketch entirely: `G = AᵀA`
//! eigensolved directly (paper §2.0.1), `U = A V Σ⁻¹` streamed.
//!
//! ## One pipeline, many executors
//!
//! The pass schedule above exists exactly once ([`pipeline`]). *Where* each
//! streaming pass runs is an [`Executor`]: [`LocalExecutor`] fans out over
//! in-process Split-Process threads, [`crate::cluster::ClusterExecutor`]
//! over remote TCP workers — same seed, same passes, same result. *How* a
//! pass's per-chunk partials collapse into one matrix is a reduction plan
//! ([`reduce`]): the default tree plan merges leaves pairwise over the
//! [`reduce::merge_rounds`] schedule (distributed across workers on a
//! cluster, `O(k²·log w)` leader state), while `ReduceMode::Star` keeps
//! the legacy leader-side fold. Entry point:
//!
//! ```ignore
//! let result = Svd::over(&input)?.rank(16).oversample(8).run()?;
//! ```

pub mod builder;
pub mod executor;
pub mod pipeline;
pub mod reduce;
pub mod result;
pub mod validate;

pub use builder::Svd;
pub use executor::{
    execute_pass_chunk, Executor, LocalExecutor, Pass, PassContext, PassOutput, WPassOutput,
};
pub use pipeline::{SvdOptions, DEFAULT_SIGMA_CUTOFF_REL};
pub use reduce::{MemGauge, ReduceMode};
pub use result::SvdResult;
// Re-exported so the two lifecycle builders read side by side:
// `Svd::over(&input)` factorizes, `Update::of(&model_dir)` appends.
pub use crate::update::{Update, UpdateResult};
