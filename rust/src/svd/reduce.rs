//! Reduction plans: how per-chunk partials become one result.
//!
//! The paper's commutative accumulations (`AᵀA`, `YᵀY`, column sums,
//! `AᵀU₀`) were originally folded by the leader, one chunk after another —
//! a star topology whose reduce work and memory grow linearly with the
//! chunk count. This module is the *plan* both executors follow instead:
//!
//! * [`merge_rounds`] — the canonical pairwise merge schedule: a
//!   stride-doubling binary tree over the chunk-ordered leaves, a pure
//!   function of the chunk count. [`LocalExecutor`](crate::svd::LocalExecutor)
//!   walks it over in-memory partials ([`tree_reduce`]);
//!   [`DistributedLeader`](crate::cluster::DistributedLeader) walks the
//!   *same* schedule by relaying pairwise merges between the workers that
//!   hold the leaves, so local and cluster reductions stay bitwise
//!   identical (per-element `f64` addition is bitwise commutative, so even
//!   operand order is free).
//! * [`band_ranges`] — the row-band decomposition of the one tall partial
//!   (`W = AᵀU₀`, `n × k'`): bands merge independently, stream through the
//!   TSQR R-factor fold ([`band_r_factor`] / [`fold_band_rs`]), and the
//!   final `V` rows are written band-by-band straight to a
//!   [`ShardSet`](crate::io::writer::ShardSet) — the leader only ever
//!   touches `k'×k'` R factors and one band in transit, `O(k²·log w)`
//!   state instead of the old `O(n·k'·chunks)`.
//! * [`MemGauge`] — the leader's accounting of exactly that reduce state,
//!   with an optional hard cap so tests (and cautious deployments) can
//!   *prove* the star path would OOM where the tree path fits.
//!
//! [`crate::splitproc::reduce_partials`] is the leaf of the tree — the one
//! pairwise merge both sides call — rather than the whole reduce.

use crate::error::{Error, Result};
use crate::linalg::tsqr::TsqrAccumulator;
use crate::linalg::{exact_svd, Matrix};

/// How an executor reduces a pass's per-chunk partials.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReduceMode {
    /// Fold partials one after another on the leader (the pre-tree
    /// behavior): simple, but leader work and memory grow with the chunk
    /// count.
    Star,
    /// Pairwise merge rounds over the [`merge_rounds`] schedule. Locally
    /// this is just a different (still deterministic) fold order; on a
    /// cluster the leaves stay on the workers that computed them and the
    /// leader only relays `k'`-scale messages.
    #[default]
    Tree,
}

impl ReduceMode {
    /// Parse a config/CLI value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "star" => Ok(ReduceMode::Star),
            "tree" => Ok(ReduceMode::Tree),
            other => Err(Error::Config(format!(
                "reduce must be `star` or `tree`, got `{other}`"
            ))),
        }
    }

    /// Stable name (inverse of [`ReduceMode::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            ReduceMode::Star => "star",
            ReduceMode::Tree => "tree",
        }
    }
}

/// One pairwise merge of the tree schedule: the span anchored at leaf
/// `dst` absorbs the span anchored at leaf `src` (`dst < src`; the merged
/// span stays anchored at `dst`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MergeStep {
    pub dst: usize,
    pub src: usize,
}

/// The canonical merge schedule for `total` chunk-ordered leaves: rounds
/// of stride-doubling pairwise merges (`1↦0, 3↦2, …`, then `2↦0, 6↦4, …`).
/// A pure function of `total`, so a restarted reduce recomputes the exact
/// same arithmetic and the distributed walk matches [`tree_reduce`] bit
/// for bit.
pub fn merge_rounds(total: usize) -> Vec<Vec<MergeStep>> {
    let mut rounds = Vec::new();
    let mut step = 1usize;
    while step < total {
        let mut round = Vec::new();
        let mut lo = 0usize;
        while lo + step < total {
            round.push(MergeStep { dst: lo, src: lo + step });
            lo += 2 * step;
        }
        if !round.is_empty() {
            rounds.push(round);
        }
        step *= 2;
    }
    rounds
}

/// Reduce chunk-ordered partials over the [`merge_rounds`] schedule, with
/// [`crate::splitproc::reduce_partials`] as the pairwise leaf. Same sum as
/// the sequential fold up to float associativity; identical bits to the
/// distributed tree walk.
pub fn tree_reduce(parts: Vec<Matrix>) -> Result<Matrix> {
    if parts.is_empty() {
        return Err(Error::Other("tree reduce over zero partials".into()));
    }
    let total = parts.len();
    let mut slots: Vec<Option<Matrix>> = parts.into_iter().map(Some).collect();
    for round in merge_rounds(total) {
        for MergeStep { dst, src } in round {
            let right = slots[src]
                .take()
                .ok_or_else(|| Error::Other("merge schedule revisited a drained slot".into()))?;
            let left = slots[dst]
                .take()
                .ok_or_else(|| Error::Other("merge schedule revisited a drained slot".into()))?;
            slots[dst] = Some(crate::splitproc::reduce_partials(vec![left, right])?);
        }
    }
    slots[0]
        .take()
        .ok_or_else(|| Error::Other("tree reduce left no root".into()))
}

/// Row bands `[lo, hi)` of a `rows`-row partial at `band_rows` rows per
/// band (`band_rows = 0` means one band spanning everything). Both sides
/// of the wire derive the same split from `(rows, band_rows)` alone.
pub fn band_ranges(rows: usize, band_rows: usize) -> Vec<(usize, usize)> {
    if rows == 0 {
        return Vec::new();
    }
    let b = if band_rows == 0 { rows } else { band_rows };
    (0..rows)
        .step_by(b.max(1))
        .map(|lo| (lo, (lo + b).min(rows)))
        .collect()
}

/// Default band height for the tall `W` partial: wide enough that the
/// per-band R factor (`k'×k'`) amortizes, capped so one band in transit
/// stays around a megabyte.
pub fn auto_band_rows(kp: usize) -> usize {
    let kp = kp.max(1);
    ((1usize << 20) / (8 * kp)).max(kp)
}

/// The TSQR R factor of one row band (`min(rows, cols) × cols`; fewer
/// rows than columns stay as-is and square up in [`fold_band_rs`]).
pub fn band_r_factor(band: &Matrix) -> Result<Matrix> {
    let mut acc = TsqrAccumulator::new(band.cols());
    acc.push_block(band)?;
    acc.finish()
}

/// Fold per-band R factors (band order) into the definitive `k'×k'` R,
/// zero-padded square so [`exact_svd`] (which wants tall input) accepts it.
pub fn fold_band_rs(kp: usize, rs: impl IntoIterator<Item = Matrix>) -> Result<Matrix> {
    let mut acc = TsqrAccumulator::new(kp);
    for r in rs {
        acc.push_block(&r)?;
    }
    let r = acc.finish()?;
    if r.rows() < kp {
        let mut padded = Matrix::zeros(kp, kp);
        for i in 0..r.rows() {
            padded.row_mut(i).copy_from_slice(r.row(i));
        }
        Ok(padded)
    } else {
        Ok(r)
    }
}

/// SVD of the folded R: `σ(W) = σ(R)` exactly, and R's right singular
/// vectors are W's — the completion's `(Σ, P)` without ever gramming W
/// (which would square its condition number).
pub fn completion_from_r(r: &Matrix) -> Result<(Vec<f64>, Matrix)> {
    let svd = exact_svd(r)?;
    Ok((svd.sigma, svd.v))
}

/// The completion's V multiplier `M_v = P_k Σ_k⁻¹` (`k'×k`): each held W
/// band times this is the corresponding band of `V`.
pub fn completion_mv(sigma_full: &[f64], p: &Matrix, k: usize, cutoff_rel: f64) -> Result<Matrix> {
    let inv = crate::svd::pipeline::guarded_inverse(&sigma_full[..k.min(sigma_full.len())], cutoff_rel);
    p.slice_cols(0, k).scale_cols(&inv)
}

/// Tracked bytes of one matrix (`f64` payload only — the accounting unit
/// of [`MemGauge`]).
pub fn matrix_bytes(m: &Matrix) -> u64 {
    (m.rows() * m.cols() * 8) as u64
}

/// Accounting of the leader's reduce-state memory: star-mode stored
/// partials, leader-held leaves shipped by hold-incapable workers, bands
/// in relay transit, fetched R factors. `cap > 0` turns the gauge into a
/// hard budget: the phase fails the moment tracked bytes exceed it — how
/// the memory-cap tests *prove* the star path needs `O(n·k'·chunks)`
/// where the tree path stays `O(k²·log w)`.
#[derive(Debug, Default)]
pub struct MemGauge {
    cur: u64,
    peak: u64,
    cap: u64,
}

impl MemGauge {
    /// Set the hard budget in bytes (0 = unlimited, track only).
    pub fn set_cap(&mut self, bytes: u64) {
        self.cap = bytes;
    }

    /// High-water mark of tracked bytes.
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Currently tracked bytes.
    pub fn current(&self) -> u64 {
        self.cur
    }

    /// Account `bytes` of reduce state; errors if a cap is set and the
    /// running total would exceed it.
    pub fn track(&mut self, bytes: u64) -> Result<()> {
        self.cur += bytes;
        self.peak = self.peak.max(self.cur);
        if self.cap > 0 && self.cur > self.cap {
            return Err(Error::Other(format!(
                "leader memory cap exceeded: {} bytes of reduce state over the {} byte cap \
                 (the star reduce stores every chunk partial leader-side; `reduce = tree` \
                 keeps the leaves on the workers)",
                self.cur, self.cap
            )));
        }
        Ok(())
    }

    /// Release previously tracked bytes.
    pub fn release(&mut self, bytes: u64) {
        self.cur = self.cur.saturating_sub(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Gaussian;
    use crate::splitproc::reduce_partials;

    fn rand(rows: usize, cols: usize, seed: u64) -> Matrix {
        let g = Gaussian::new(seed);
        Matrix::from_fn(rows, cols, |i, j| g.sample(i as u64, j as u64))
    }

    #[test]
    fn merge_rounds_shapes() {
        assert!(merge_rounds(0).is_empty());
        assert!(merge_rounds(1).is_empty());
        // 3 leaves: (1↦0), then (2↦0).
        assert_eq!(
            merge_rounds(3),
            vec![
                vec![MergeStep { dst: 0, src: 1 }],
                vec![MergeStep { dst: 0, src: 2 }]
            ]
        );
        // 6 leaves: (1↦0, 3↦2, 5↦4), (2↦0), (4↦0).
        assert_eq!(
            merge_rounds(6),
            vec![
                vec![
                    MergeStep { dst: 0, src: 1 },
                    MergeStep { dst: 2, src: 3 },
                    MergeStep { dst: 4, src: 5 }
                ],
                vec![MergeStep { dst: 0, src: 2 }],
                vec![MergeStep { dst: 0, src: 4 }]
            ]
        );
        // Every leaf is consumed exactly once and the root is leaf 0.
        for total in 1..40 {
            let mut absorbed = vec![false; total];
            for round in merge_rounds(total) {
                for MergeStep { dst, src } in round {
                    assert!(dst < src && src < total);
                    assert!(!absorbed[src], "leaf {src} absorbed twice (total {total})");
                    assert!(!absorbed[dst], "merging into drained leaf {dst}");
                    absorbed[src] = true;
                }
            }
            let roots = absorbed.iter().filter(|&&a| !a).count();
            assert_eq!(roots, 1, "total {total}");
            assert!(!absorbed[0]);
        }
    }

    #[test]
    fn tree_reduce_matches_sequential_on_integer_fixture() {
        // Small integers: the sequential fold is exact, so tree == star
        // bit for bit regardless of association.
        for total in [1usize, 2, 3, 5, 7, 8, 13] {
            let parts: Vec<Matrix> =
                (0..total).map(|i| Matrix::from_fn(3, 2, |r, c| (i + 2 * r + c) as f64)).collect();
            let star = reduce_partials(parts.clone()).unwrap();
            let tree = tree_reduce(parts).unwrap();
            assert_eq!(star.max_abs_diff(&tree), 0.0, "total {total}");
        }
    }

    #[test]
    fn tree_reduce_close_to_sequential_on_random_fixture() {
        let parts: Vec<Matrix> = (0..11).map(|i| rand(6, 4, 100 + i)).collect();
        let star = reduce_partials(parts.clone()).unwrap();
        let tree = tree_reduce(parts).unwrap();
        assert!(star.max_abs_diff(&tree) < 1e-12 * star.max_abs().max(1.0));
    }

    #[test]
    fn tree_reduce_is_deterministic() {
        let parts: Vec<Matrix> = (0..9).map(|i| rand(5, 5, 200 + i)).collect();
        let a = tree_reduce(parts.clone()).unwrap();
        let b = tree_reduce(parts).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn tree_reduce_empty_is_error() {
        assert!(tree_reduce(Vec::new()).is_err());
    }

    #[test]
    fn band_ranges_cover_and_partition() {
        assert_eq!(band_ranges(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(band_ranges(10, 0), vec![(0, 10)]);
        assert_eq!(band_ranges(3, 100), vec![(0, 3)]);
        assert!(band_ranges(0, 4).is_empty());
        let bands = band_ranges(97, 13);
        assert_eq!(bands.first().unwrap().0, 0);
        assert_eq!(bands.last().unwrap().1, 97);
        for w in bands.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn auto_band_rows_bounds() {
        assert_eq!(auto_band_rows(16), (1 << 20) / 128);
        // Very wide sketches still get at least kp rows per band.
        assert_eq!(auto_band_rows(100_000), 100_000);
        assert!(auto_band_rows(0) >= 1);
    }

    #[test]
    fn banded_r_fold_matches_whole_matrix_sigma() {
        let w = rand(120, 6, 9);
        let whole = {
            let r = fold_band_rs(6, vec![band_r_factor(&w).unwrap()]).unwrap();
            completion_from_r(&r).unwrap().0
        };
        let banded = {
            let rs: Vec<Matrix> = band_ranges(120, 17)
                .into_iter()
                .map(|(lo, hi)| band_r_factor(&w.slice_rows(lo, hi)).unwrap())
                .collect();
            let r = fold_band_rs(6, rs).unwrap();
            completion_from_r(&r).unwrap().0
        };
        let want = exact_svd(&w).unwrap().sigma;
        for i in 0..6 {
            assert!((whole[i] - want[i]).abs() < 1e-9 * want[0], "{i}");
            assert!((banded[i] - want[i]).abs() < 1e-9 * want[0], "{i}");
        }
    }

    #[test]
    fn completion_reconstructs_v() {
        // V = W · P_k Σ_k⁻¹ must reproduce W's right singular vectors.
        let w = rand(80, 5, 3);
        let rs: Vec<Matrix> = band_ranges(80, 32)
            .into_iter()
            .map(|(lo, hi)| band_r_factor(&w.slice_rows(lo, hi)).unwrap())
            .collect();
        let r = fold_band_rs(5, rs).unwrap();
        let (sigma, p) = completion_from_r(&r).unwrap();
        let mv = completion_mv(&sigma, &p, 3, 1e-12).unwrap();
        let v = crate::linalg::matmul(&w, &mv).unwrap();
        let exact = exact_svd(&w).unwrap();
        for j in 0..3 {
            // up to sign
            let dot: f64 = (0..5).map(|i| v.get(i, j) * exact.v.get(i, j)).sum();
            let sign = if dot < 0.0 { -1.0 } else { 1.0 };
            for i in 0..5 {
                assert!(
                    (v.get(i, j) - sign * exact.v.get(i, j)).abs() < 1e-9,
                    "v[{i},{j}]"
                );
            }
        }
    }

    #[test]
    fn reduce_mode_parse_roundtrip() {
        assert_eq!(ReduceMode::parse("star").unwrap(), ReduceMode::Star);
        assert_eq!(ReduceMode::parse("tree").unwrap(), ReduceMode::Tree);
        assert!(ReduceMode::parse("ring").is_err());
        assert_eq!(ReduceMode::default(), ReduceMode::Tree);
        assert_eq!(ReduceMode::Tree.name(), "tree");
    }

    #[test]
    fn mem_gauge_tracks_peak_and_cap() {
        let mut g = MemGauge::default();
        g.track(100).unwrap();
        g.track(50).unwrap();
        g.release(100);
        assert_eq!(g.current(), 50);
        assert_eq!(g.peak(), 150);
        g.set_cap(60);
        assert!(g.track(5).is_ok());
        let err = g.track(100).unwrap_err().to_string();
        assert!(err.contains("memory cap exceeded"), "{err}");
        assert_eq!(matrix_bytes(&Matrix::zeros(3, 4)), 96);
    }
}
