//! Pipeline orchestration (leader side).

use crate::backend::BackendRef;
use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::writer::ShardSet;
use crate::io::InputSpec;
use crate::jobs::{Pass2Job, ProjectGramJob};
use crate::linalg::{matmul, Matrix};
use crate::metrics::PhaseReport;
use crate::rng::VirtualMatrix;
use crate::splitproc::{self, Blocked};
use crate::svd::result::SvdResult;
use crate::util::Logger;
use std::time::Instant;

static LOG: Logger = Logger::new("svd");

/// Options for the SVD drivers (a trimmed view of
/// [`crate::config::RunConfig`]).
#[derive(Clone, Debug)]
pub struct SvdOptions {
    pub k: usize,
    pub oversample: usize,
    pub power_iters: usize,
    pub workers: usize,
    pub block: usize,
    pub seed: u64,
    pub work_dir: String,
    pub compute_v: bool,
    /// Shard format for Y/U0/U intermediates (Bin is faster; Csv matches
    /// the paper's artifacts).
    pub shard_format: InputFormat,
    /// PCA mode: subtract per-column means (one cheap extra streaming
    /// pass); the factorization is then of `A - 1 mu^T`.
    pub center: bool,
}

impl Default for SvdOptions {
    fn default() -> Self {
        SvdOptions {
            k: 16,
            oversample: 8,
            power_iters: 0,
            workers: 4,
            block: 256,
            seed: 0,
            work_dir: std::env::temp_dir()
                .join("tallfat_svd")
                .to_string_lossy()
                .into_owned(),
            compute_v: true,
            shard_format: InputFormat::Bin,
            center: false,
        }
    }
}

impl SvdOptions {
    pub fn from_config(cfg: &crate::config::RunConfig) -> Self {
        SvdOptions {
            k: cfg.k,
            oversample: cfg.oversample,
            power_iters: cfg.power_iters,
            workers: cfg.workers,
            block: cfg.block,
            seed: cfg.seed,
            work_dir: cfg.work_dir.clone(),
            compute_v: cfg.compute_v,
            shard_format: InputFormat::Bin,
            center: cfg.center,
        }
    }
}

/// Cutoff-guarded inverse of singular values: columns below
/// `cutoff_rel * sigma_max` are zeroed (rank deficiency / oversampled tail).
/// Shared with the serve layer's projection matrix `V Σ⁻¹`.
pub(crate) fn guarded_inverse(sigma: &[f64], cutoff_rel: f64) -> Vec<f64> {
    let smax = sigma.first().copied().unwrap_or(0.0).max(1e-300);
    sigma
        .iter()
        .map(|&s| if s > cutoff_rel * smax { 1.0 / s } else { 0.0 })
        .collect()
}

/// Run the paper's randomized rank-k SVD over a file. See module docs for
/// the pass structure.
pub fn randomized_svd_file(input: &InputSpec, backend: BackendRef, opts: &SvdOptions) -> Result<SvdResult> {
    let mut report = PhaseReport::new();
    let (m_rows, n) = input.dims()?;
    if m_rows == 0 || n == 0 {
        return Err(Error::Config("empty input matrix".into()));
    }
    let kp = (opts.k + opts.oversample).min(n).min(m_rows);
    LOG.info(&format!(
        "randomized svd: {m_rows}x{n} -> k={} (sketch {kp}), workers={}, block={}, backend={}",
        opts.k.min(kp),
        opts.workers,
        opts.block,
        backend.name()
    ));
    std::fs::create_dir_all(&opts.work_dir)?;

    let y_shards = ShardSet::new(&opts.work_dir, "Y", opts.shard_format)?;
    let u0_shards = ShardSet::new(&opts.work_dir, "U0", opts.shard_format)?;
    let u_shards = ShardSet::new(&opts.work_dir, "U", opts.shard_format)?;

    // PCA mode: pass 0 computes column means (Welford per worker, merged);
    // all later passes subtract them on the fly via `CenteredJob`.
    let means: std::sync::Arc<Vec<f64>> = if opts.center {
        let t0 = Instant::now();
        let results = splitproc::run(input, opts.workers, |_| {
            Ok(crate::jobs::ColStatsJob::new(n))
        })?;
        let mut iter = results.into_iter().map(|r| r.job);
        let mut acc = iter.next().ok_or_else(|| Error::Other("no chunks".into()))?;
        for j in iter {
            acc.merge(&j)?;
        }
        report.push("pass0.colstats", t0.elapsed(), acc.count(), 0);
        std::sync::Arc::new(acc.means().to_vec())
    } else {
        std::sync::Arc::new(Vec::new())
    };

    // The virtual sketch Ω (n x kp): workers materialize identical bits.
    let vm = VirtualMatrix::projection(opts.seed, n, kp);
    let mut omega = vm.materialize();
    let mut shards_count;

    let mut w_mat;
    let mut u0_valid;
    let mut iteration = 0usize;
    loop {
        // ---- pass 1: Y = A Ω, G = YᵀY ------------------------------------
        let t0 = Instant::now();
        let omega_ref = &omega;
        let means_ref = &means;
        let results = splitproc::run(input, opts.workers, |chunk| {
            let job = ProjectGramJob::new(
                backend.clone(),
                omega_ref.clone(),
                &y_shards,
                chunk.index,
            )?;
            Ok(splitproc::CenteredJob::new(
                Blocked::new(job, opts.block, n),
                means_ref.clone(),
            ))
        })?;
        shards_count = results.len();
        let rows_seen: u64 = results.iter().map(|r| r.rows).sum();
        if rows_seen as usize != m_rows {
            return Err(Error::Other(format!(
                "pass1 saw {rows_seen} rows, expected {m_rows}"
            )));
        }
        let partials: Vec<Matrix> = results
            .into_iter()
            .map(|r| r.job.into_inner().into_inner().into_gram_partial())
            .collect();
        let g = splitproc::reduce_partials(partials)?;
        report.push(&format!("pass1.project_gram[{iteration}]"), t0.elapsed(), rows_seen, 0);

        // ---- leader: eigh(G), M = V_y Σ_y⁻¹ ------------------------------
        let t0 = Instant::now();
        let (w_eig, v_y) = backend.eigh(&g)?;
        let sig_y: Vec<f64> = w_eig.iter().map(|&w| w.max(0.0).sqrt()).collect();
        let inv_y = guarded_inverse(&sig_y, 1e-7);
        let m_mat = v_y.scale_cols(&inv_y)?;
        report.push(&format!("leader.eigh_y[{iteration}]"), t0.elapsed(), kp as u64, 0);

        // ---- pass 2: U0 = Y M, W = Aᵀ U0 ---------------------------------
        let t0 = Instant::now();
        let m_ref = &m_mat;
        let means_ref = &means;
        let results = splitproc::run(input, opts.workers, |chunk| {
            let job = Pass2Job::new(
                backend.clone(),
                m_ref.clone(),
                &y_shards,
                &u0_shards,
                chunk.index,
                n,
            )?;
            Ok(splitproc::CenteredJob::new(
                Blocked::new(job, opts.block, n),
                means_ref.clone(),
            ))
        })?;
        let rows2: u64 = results.iter().map(|r| r.rows).sum();
        let w_partials: Vec<Matrix> = results
            .into_iter()
            .map(|r| r.job.into_inner().into_inner().into_w_partial())
            .collect();
        w_mat = splitproc::reduce_partials(w_partials)?;
        u0_valid = true;
        report.push(&format!("pass2.urecover_tmul[{iteration}]"), t0.elapsed(), rows2, 0);

        if iteration >= opts.power_iters {
            break;
        }
        // ---- power iteration: Ω ← orth(W), repeat ------------------------
        let t0 = Instant::now();
        let (q, _) = crate::linalg::thin_qr(&w_mat)?;
        omega = q;
        iteration += 1;
        report.push(&format!("leader.power_orth[{iteration}]"), t0.elapsed(), 0, 0);
    }
    let _ = u0_valid;

    // ---- leader: small SVD completion from W -----------------------------
    let t0 = Instant::now();
    let gw = backend.gram_block(&w_mat)?; // WᵀW, kp x kp
    let (w2, p) = backend.eigh(&gw)?;
    let sigma_full: Vec<f64> = w2.iter().map(|&w| w.max(0.0).sqrt()).collect();
    let k = opts.k.min(kp);
    let sigma: Vec<f64> = sigma_full[..k].to_vec();
    let p_k = p.slice_cols(0, k); // kp x k rotation
    let v = if opts.compute_v {
        let inv_s = guarded_inverse(&sigma, 1e-12);
        let vp = matmul(&w_mat, &p_k)?; // n x k
        Some(vp.scale_cols(&inv_s)?)
    } else {
        None
    };
    report.push("leader.eigh_w", t0.elapsed(), kp as u64, 0);

    // ---- pass 3: U = U0 P_k (rotate shards) ------------------------------
    let t0 = Instant::now();
    let rows3 = rotate_shards(&u0_shards, &u_shards, shards_count, &p_k, opts.block)?;
    report.push("pass3.rotate_u", t0.elapsed(), rows3, 0);

    LOG.info(&format!(
        "randomized svd done: sigma[0]={:.4} sigma[{}]={:.4}",
        sigma.first().copied().unwrap_or(0.0),
        k.saturating_sub(1),
        sigma.last().copied().unwrap_or(0.0)
    ));
    Ok(SvdResult {
        m: m_rows,
        n,
        k,
        sigma,
        v,
        u_shards,
        shards: shards_count,
        means: if opts.center { Some(means.to_vec()) } else { None },
        report,
    })
}

/// Rotate every shard's rows by `p` (`kp x k`): `U = U0 P`. Streams shard by
/// shard with one worker thread per shard.
fn rotate_shards(
    src: &ShardSet,
    dst: &ShardSet,
    shards: usize,
    p: &Matrix,
    block: usize,
) -> Result<u64> {
    let counts: Vec<Result<u64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..shards)
            .map(|i| {
                scope.spawn(move || -> Result<u64> {
                    let mut reader = src.open_reader(i)?;
                    let mut writer = dst.open_writer(i, p.cols())?;
                    let mut row = Vec::new();
                    let mut buf: Vec<Vec<f64>> = Vec::with_capacity(block);
                    let mut count = 0u64;
                    loop {
                        buf.clear();
                        while buf.len() < block {
                            if !reader.next_row(&mut row)? {
                                break;
                            }
                            buf.push(row.clone());
                        }
                        if buf.is_empty() {
                            break;
                        }
                        let u0 = Matrix::from_rows(&buf)?;
                        let u = matmul(&u0, p)?;
                        for r in 0..u.rows() {
                            writer.write_row(u.row(r))?;
                        }
                        count += u.rows() as u64;
                        if buf.len() < block {
                            break;
                        }
                    }
                    writer.finish()?;
                    Ok(count)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(Error::Other("rotate worker panicked".into()))))
            .collect()
    });
    let mut total = 0u64;
    for c in counts {
        total += c?;
    }
    Ok(total)
}

/// The paper's small-n exact route (§2.0.1): eigendecompose `AᵀA` directly,
/// then stream `U = A V Σ⁻¹`.
pub fn gram_svd_file(input: &InputSpec, backend: BackendRef, opts: &SvdOptions) -> Result<SvdResult> {
    let mut report = PhaseReport::new();
    let (m_rows, n) = input.dims()?;
    if m_rows == 0 || n == 0 {
        return Err(Error::Config("empty input matrix".into()));
    }
    let k = opts.k.min(n).min(m_rows);
    LOG.info(&format!(
        "gram svd: {m_rows}x{n} -> k={k}, workers={}, backend={}",
        opts.workers,
        backend.name()
    ));
    std::fs::create_dir_all(&opts.work_dir)?;
    let u_shards = ShardSet::new(&opts.work_dir, "U", opts.shard_format)?;

    // ---- pass 1: G = AᵀA --------------------------------------------------
    let t0 = Instant::now();
    let backend2 = backend.clone();
    let results = splitproc::run(input, opts.workers, |_chunk| {
        let job = crate::jobs::AtaBlockJob::new(backend2.clone(), n);
        Ok(Blocked::new(job, opts.block, n))
    })?;
    let shards_count = results.len();
    let rows_seen: u64 = results.iter().map(|r| r.rows).sum();
    let partials: Vec<Matrix> = results
        .into_iter()
        .map(|r| r.job.into_inner().into_partial())
        .collect();
    let g = splitproc::reduce_partials(partials)?;
    report.push("pass1.ata", t0.elapsed(), rows_seen, 0);

    // ---- leader: eigh(G) = V Σ² Vᵀ -----------------------------------------
    let t0 = Instant::now();
    let (w_eig, v_full) = backend.eigh(&g)?;
    let sigma_full: Vec<f64> = w_eig.iter().map(|&w| w.max(0.0).sqrt()).collect();
    let sigma: Vec<f64> = sigma_full[..k].to_vec();
    let v_k = v_full.slice_cols(0, k);
    let inv_s = guarded_inverse(&sigma, 1e-12);
    // M = V_k Σ⁻¹ : the paper's U = A V Σ⁻¹ per-block multiplier.
    let m_mat = v_k.scale_cols(&inv_s)?;
    report.push("leader.eigh", t0.elapsed(), n as u64, 0);

    // ---- pass 2: U = A M ----------------------------------------------------
    let t0 = Instant::now();
    let m_ref = &m_mat;
    let results = splitproc::run(input, opts.workers, |chunk| {
        let job = crate::jobs::MultJob::new(
            backend.clone(),
            m_ref.clone(),
            &u_shards,
            chunk.index,
        )?;
        Ok(Blocked::new(job, opts.block, n))
    })?;
    let rows2: u64 = results.iter().map(|r| r.rows).sum();
    report.push("pass2.u_recover", t0.elapsed(), rows2, 0);

    Ok(SvdResult {
        m: m_rows,
        n,
        k,
        sigma,
        v: Some(v_k),
        u_shards,
        means: None,
        shards: shards_count,
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::io::dataset::{gen_exact, Spectrum};
    use std::sync::Arc;

    fn setup(name: &str, m: usize, n: usize, rank: usize, noise: f64) -> (InputSpec, Matrix, Vec<f64>) {
        let dir = std::env::temp_dir().join("tallfat_test_svd").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (a, sigma) = gen_exact(
            m,
            n,
            rank,
            Spectrum::Geometric { scale: 10.0, decay: 0.6 },
            noise,
            42,
        )
        .unwrap();
        let spec = InputSpec::csv(dir.join("A.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        (spec, a, sigma)
    }

    fn opts(name: &str, k: usize) -> SvdOptions {
        SvdOptions {
            k,
            oversample: 8,
            workers: 3,
            block: 32,
            work_dir: std::env::temp_dir()
                .join("tallfat_test_svd")
                .join(name)
                .join("work")
                .to_string_lossy()
                .into_owned(),
            ..Default::default()
        }
    }

    #[test]
    fn randomized_recovers_low_rank_exactly() {
        let (spec, a, sigma_true) = setup("rand_exact", 300, 24, 6, 0.0);
        let r = randomized_svd_file(&spec, Arc::new(NativeBackend::new()), &opts("rand_exact", 8))
            .unwrap();
        assert_eq!(r.k, 8);
        for i in 0..6 {
            assert!(
                (r.sigma[i] - sigma_true[i]).abs() < 1e-6 * sigma_true[0],
                "sigma[{i}]: {} vs {}",
                r.sigma[i],
                sigma_true[i]
            );
        }
        // Reconstruction: rank-6 matrix from rank-8 factorization is exact.
        let recon = r.reconstruct().unwrap();
        let rel = recon.max_abs_diff(&a) / a.max_abs();
        assert!(rel < 1e-6, "rel {rel}");
    }

    #[test]
    fn randomized_with_noise_close_to_exact() {
        let (spec, a, _) = setup("rand_noise", 400, 32, 8, 0.01);
        let r = randomized_svd_file(&spec, Arc::new(NativeBackend::new()), &opts("rand_noise", 8))
            .unwrap();
        let exact = crate::linalg::exact_svd(&a).unwrap();
        for i in 0..4 {
            let rel = (r.sigma[i] - exact.sigma[i]).abs() / exact.sigma[i];
            assert!(rel < 0.05, "sigma[{i}] rel err {rel}");
        }
    }

    #[test]
    fn power_iterations_improve_slow_decay() {
        let dir = std::env::temp_dir().join("tallfat_test_svd").join("power");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (a, _) = gen_exact(300, 40, 40, Spectrum::Power { scale: 10.0 }, 0.0, 7).unwrap();
        let spec = InputSpec::csv(dir.join("A.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        let exact = crate::linalg::exact_svd(&a).unwrap();

        let run = |q: usize, name: &str| {
            let mut o = opts(name, 8);
            o.power_iters = q;
            o.oversample = 4;
            let r = randomized_svd_file(&spec, Arc::new(NativeBackend::new()), &o).unwrap();
            let recon = r.reconstruct().unwrap();
            let mut diff = 0.0f64;
            for i in 0..300 {
                for j in 0..40 {
                    diff += (recon.get(i, j) - a.get(i, j)).powi(2);
                }
            }
            diff.sqrt()
        };
        let err0 = run(0, "power0");
        let err2 = run(2, "power2");
        let tail: f64 = exact.sigma[8..].iter().map(|s| s * s).sum::<f64>().sqrt();
        assert!(err2 < err0 * 1.001, "q=2 ({err2}) should not be worse than q=0 ({err0})");
        assert!(err2 < 1.25 * tail, "q=2 err {err2} vs tail {tail}");
    }

    #[test]
    fn gram_route_matches_exact() {
        let (spec, a, _) = setup("gram", 200, 16, 16, 0.005);
        let r = gram_svd_file(&spec, Arc::new(NativeBackend::new()), &opts("gram", 16)).unwrap();
        let exact = crate::linalg::exact_svd(&a).unwrap();
        for i in 0..16 {
            let denom = exact.sigma[i].max(1e-9);
            assert!(
                (r.sigma[i] - exact.sigma[i]).abs() / denom < 1e-3,
                "sigma[{i}]: {} vs {}",
                r.sigma[i],
                exact.sigma[i]
            );
        }
        let recon = r.reconstruct().unwrap();
        assert!(recon.max_abs_diff(&a) < 1e-6 * a.max_abs().max(1.0));
    }

    #[test]
    fn worker_count_does_not_change_result() {
        let (spec, _, _) = setup("workers", 150, 12, 5, 0.0);
        let run = |w: usize, name: &str| {
            let mut o = opts(name, 6);
            o.workers = w;
            randomized_svd_file(&spec, Arc::new(NativeBackend::new()), &o)
                .unwrap()
                .sigma
        };
        let s1 = run(1, "w1");
        let s4 = run(4, "w4");
        for (a, b) in s1.iter().zip(s4.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }
}
