//! The executor-generic SVD driver — the paper's pass schedule, once.
//!
//! Every route (randomized sketch, exact Gram, PCA centering, power
//! iteration) is expressed as a sequence of [`Pass`]es handed to an
//! [`Executor`]; the leader-side math between passes lives here and only
//! ever touches `k' x k'` matrices. Run it through the [`crate::svd::Svd`]
//! builder — the sole entry point since the deprecated free functions of
//! the pre-builder releases were removed.

use crate::backend::BackendRef;
use crate::config::InputFormat;
use crate::error::{Error, Result};
use crate::io::writer::ShardSet;
use crate::io::InputSpec;
use crate::linalg::Matrix;
use crate::metrics::PhaseReport;
use crate::splitproc::SchedStats;
use crate::svd::executor::{Executor, Pass, PassContext};
use crate::svd::reduce::ReduceMode;
use crate::svd::result::SvdResult;
use crate::util::Logger;
use std::sync::Arc;
use std::time::Instant;

static LOG: Logger = Logger::new("svd");

/// Default relative cutoff under which sketch-stage singular values are
/// treated as zero (rank deficiency / oversampled tail). Builder-settable
/// via [`crate::svd::Svd::sigma_cutoff_rel`].
pub const DEFAULT_SIGMA_CUTOFF_REL: f64 = 1e-7;

/// Cutoff for the final completion's `Σ⁻¹` — numerically-zero tail only.
pub(crate) const COMPLETION_CUTOFF_REL: f64 = 1e-12;

/// Options for the SVD driver (a trimmed view of
/// [`crate::config::RunConfig`]; build one fluently with
/// [`crate::svd::Svd`]).
#[derive(Clone, Debug)]
pub struct SvdOptions {
    pub k: usize,
    pub oversample: usize,
    pub power_iters: usize,
    pub workers: usize,
    pub block: usize,
    pub seed: u64,
    pub work_dir: String,
    pub compute_v: bool,
    /// Shard format for Y/U0/U intermediates (Bin is faster; Csv matches
    /// the paper's artifacts).
    pub shard_format: InputFormat,
    /// PCA mode: subtract per-column means (one cheap extra streaming
    /// pass); the factorization is then of `A - 1 mu^T`.
    pub center: bool,
    /// Skip the sketch and eigendecompose `AᵀA` directly (paper §2.0.1,
    /// small n).
    pub exact_gram: bool,
    /// Relative cutoff for the sketch-stage guarded inverse
    /// `M = V_y Σ_y⁻¹`: columns with `σ <= cutoff * σ_max` are zeroed.
    pub sigma_cutoff_rel: f64,
    /// Rows per scheduler chunk (0 = derive from `chunks_per_worker`).
    pub chunk_rows: usize,
    /// Chunks planned per worker when `chunk_rows = 0` (1 = the old
    /// static one-chunk-per-worker schedule).
    pub chunks_per_worker: usize,
    /// Retry budget per chunk before a pass fails.
    pub chunk_retries: usize,
    /// Target relative residual for adaptive routes (`tallfat stream`).
    /// The multi-pass routes carry it for config parity but work at the
    /// requested `k` regardless; validation rejects `tol <= 0` either way
    /// so a config-file `tol` is never silently parsed-but-ignored.
    pub tol: f64,
    /// How chunk partials are reduced: the canonical pairwise merge tree
    /// ([`ReduceMode::Tree`], default — distributed across workers in
    /// cluster mode, leader state `O(k'²·log workers)`) or the pre-v6
    /// sequential star fold ([`ReduceMode::Star`]).
    pub reduce: ReduceMode,
    /// Row-band height for the tall `W` reduction and the staged `V`
    /// shards (0 = auto-size from the sketch width).
    pub band_rows: usize,
    /// Re-plan the chunk granularity between passes from measured chunk
    /// wall times (only when `chunk_rows = 0`; `--no-adaptive-chunks`
    /// turns it off).
    pub adaptive_chunks: bool,
    /// Materialize `V` as a dense in-memory matrix on the leader (the
    /// default; serving and reconstruction read it directly). Off, the
    /// leader never holds an n-sized matrix — V stays as staged row
    /// shards ([`SvdResult::v_shards`]).
    pub materialize_v: bool,
}

impl Default for SvdOptions {
    fn default() -> Self {
        SvdOptions {
            k: 16,
            oversample: 8,
            power_iters: 0,
            workers: 4,
            block: 256,
            seed: 0,
            work_dir: std::env::temp_dir()
                .join("tallfat_svd")
                .to_string_lossy()
                .into_owned(),
            compute_v: true,
            shard_format: InputFormat::Bin,
            center: false,
            exact_gram: false,
            sigma_cutoff_rel: DEFAULT_SIGMA_CUTOFF_REL,
            chunk_rows: 0,
            chunks_per_worker: crate::splitproc::sched::DEFAULT_CHUNKS_PER_WORKER,
            chunk_retries: crate::splitproc::sched::DEFAULT_CHUNK_RETRIES,
            tol: crate::stream::DEFAULT_TOL,
            reduce: ReduceMode::default(),
            band_rows: 0,
            adaptive_chunks: true,
            materialize_v: true,
        }
    }
}

impl SvdOptions {
    /// Validate option invariants. Every driver entry point calls this, so
    /// the fluent builder rejects bad values (`block(0)`, `rank(0)`, an
    /// out-of-range cutoff) with a clear config error instead of panicking
    /// deep inside a worker.
    pub fn validate(&self) -> Result<()> {
        if self.k == 0 {
            return Err(Error::Config("k must be >= 1".into()));
        }
        if self.workers == 0 {
            return Err(Error::Config("workers must be >= 1".into()));
        }
        if self.block == 0 {
            return Err(Error::Config("block must be >= 1".into()));
        }
        if !(0.0..1.0).contains(&self.sigma_cutoff_rel) {
            return Err(Error::Config(format!(
                "sigma_cutoff_rel must be in [0, 1), got {}",
                self.sigma_cutoff_rel
            )));
        }
        if self.chunks_per_worker == 0 {
            return Err(Error::Config("chunks_per_worker must be >= 1".into()));
        }
        if !(self.tol > 0.0 && self.tol.is_finite()) {
            return Err(Error::Config(format!(
                "tol must be a positive finite residual target, got {}",
                self.tol
            )));
        }
        if self.shard_format.is_sparse() {
            return Err(Error::Config(format!(
                "shard_format must be csv or bin (shards hold dense factor rows), got {:?}",
                self.shard_format
            )));
        }
        Ok(())
    }

    /// The chunk-scheduling view of these options.
    pub fn sched_policy(&self) -> crate::splitproc::SchedPolicy {
        crate::splitproc::SchedPolicy {
            chunk_rows: self.chunk_rows,
            chunks_per_worker: self.chunks_per_worker,
            max_retries: self.chunk_retries,
        }
    }
}

/// Cutoff-guarded inverse of singular values: columns below
/// `cutoff_rel * sigma_max` are zeroed (rank deficiency / oversampled tail).
/// Shared with the serve layer's projection matrix `V Σ⁻¹`.
pub(crate) fn guarded_inverse(sigma: &[f64], cutoff_rel: f64) -> Vec<f64> {
    let smax = sigma.first().copied().unwrap_or(0.0).max(1e-300);
    sigma
        .iter()
        .map(|&s| if s > cutoff_rel * smax { 1.0 / s } else { 0.0 })
        .collect()
}

/// Read input dimensions and reject degenerate inputs — the single
/// validation gate in front of every driver entry point. Non-seekable
/// sources (stdin, pipes) are rejected here with a pointer at
/// `tallfat stream`, before any pass tries to re-read them.
pub(crate) fn checked_dims(input: &InputSpec) -> Result<(usize, usize)> {
    crate::io::ensure_seekable(&input.path)?;
    let (m, n) = input.dims()?;
    if m == 0 || n == 0 {
        return Err(Error::Config(format!(
            "empty input matrix ({m}x{n}): {}",
            input.path
        )));
    }
    Ok((m, n))
}

/// Run the paper's rank-k SVD over `input` with every streaming pass
/// delegated to `exec`. The one and only implementation of the pass
/// schedule — both the local and the distributed entry points land here.
pub(crate) fn run_svd(
    exec: &mut dyn Executor,
    input: &InputSpec,
    dims: (usize, usize),
    backend: BackendRef,
    opts: &SvdOptions,
) -> Result<SvdResult> {
    opts.validate()?;
    let (m_rows, n) = dims;
    let mut report = PhaseReport::new();
    let kp = if opts.exact_gram {
        opts.k.min(n).min(m_rows)
    } else {
        (opts.k + opts.oversample).min(n).min(m_rows)
    };
    let mut ctx = PassContext {
        input,
        backend,
        work_dir: &opts.work_dir,
        shard_format: opts.shard_format,
        block: opts.block,
        seed: opts.seed,
        n,
        kp,
        means: Arc::new(Vec::new()),
        sched: opts.sched_policy(),
        shard_epoch: 0,
        reduce: opts.reduce,
        band_rows: opts.band_rows,
    };
    LOG.info(&format!(
        "{} svd: {m_rows}x{n} -> k={} (sketch {kp}), executor={}, backend={}",
        if opts.exact_gram { "gram" } else { "randomized" },
        opts.k.min(kp),
        exec.name(),
        ctx.backend.name()
    ));
    std::fs::create_dir_all(&opts.work_dir)?;
    // Clear staged-shard litter from earlier crashed runs of this work
    // dir (no writers are active yet, so the sweep cannot race one).
    crate::io::writer::sweep_stale_stages(&opts.work_dir);

    // ---- pass 0 (PCA mode): column means, subtracted on the fly later ----
    if opts.center {
        let t0 = Instant::now();
        let out = exec.run_pass(&ctx, &Pass::ColStats)?;
        if out.rows as usize != m_rows {
            return Err(Error::Other(format!(
                "pass0 saw {} rows, expected {m_rows}",
                out.rows
            )));
        }
        let sums = out
            .partial
            .ok_or_else(|| Error::Other("colstats pass returned no partial".into()))?;
        let means: Vec<f64> = sums.row(0).iter().map(|&s| s / out.rows as f64).collect();
        ctx.means = Arc::new(means);
        report.push("pass0.colstats", t0.elapsed(), out.rows, 0);
        // A full streaming pass just ran: its chunk timings are the first
        // granularity measurement, and no shards depend on the plan yet.
        adapt_chunk_rows(&mut ctx, opts, &out.stats, m_rows);
    }

    let route = if opts.exact_gram {
        gram_passes(exec, &ctx, m_rows, &mut report)?
    } else {
        randomized_passes(exec, &mut ctx, opts, m_rows, &mut report)?
    };

    let u_shards = ShardSet::new(&opts.work_dir, "U", opts.shard_format)?;
    LOG.info(&format!(
        "svd done: sigma[0]={:.4} sigma[{}]={:.4}",
        route.sigma.first().copied().unwrap_or(0.0),
        route.k.saturating_sub(1),
        route.sigma.last().copied().unwrap_or(0.0)
    ));
    Ok(SvdResult {
        m: m_rows,
        n,
        k: route.k,
        sigma: route.sigma,
        v: route.v,
        v_shards: route.v_shards,
        v_bands: route.v_bands,
        u_shards,
        shards: route.shards,
        means: if opts.center { Some(ctx.means.to_vec()) } else { None },
        report,
    })
}

/// What a route (randomized or exact-Gram) hands back to [`run_svd`].
struct RouteOutput {
    k: usize,
    sigma: Vec<f64>,
    v: Option<Matrix>,
    shards: usize,
    v_shards: Option<ShardSet>,
    v_bands: usize,
}

/// Aim each chunk at roughly this much wall time when re-planning:
/// large enough that scheduling overhead is negligible, small enough
/// that retries and speculative re-runs stay cheap.
const ADAPTIVE_CHUNK_TARGET_MS: f64 = 500.0;
/// Below this median chunk time the measurement is scheduler noise.
const ADAPTIVE_CHUNK_MIN_MS: f64 = 20.0;

/// Re-plan `chunk_rows` from the previous pass's measured per-chunk wall
/// times (the same samples published to `sched_chunk_ms{pass=…}`). Only
/// runs at plan-safe boundaries — call sites are after pass 0 and between
/// power-iteration rounds, never inside a round, because a round's
/// recovery/rotation passes read the shards its projection pass wrote and
/// the shard fan-out *is* the chunk plan. Conservative by design: the
/// user's explicit `chunk_rows` wins, sub-noise medians are ignored, and
/// only a ≥2× correction is worth invalidating the measured plan for.
fn adapt_chunk_rows(ctx: &mut PassContext, opts: &SvdOptions, stats: &SchedStats, m_rows: usize) {
    if !opts.adaptive_chunks || opts.chunk_rows != 0 {
        return;
    }
    let mut ms = stats.chunk_ms.clone();
    if ms.is_empty() || stats.chunks == 0 {
        return;
    }
    ms.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let p50 = ms[ms.len() / 2];
    if p50 < ADAPTIVE_CHUNK_MIN_MS {
        return;
    }
    let cur_rows = (m_rows / stats.chunks).max(1);
    let scaled = (cur_rows as f64 * ADAPTIVE_CHUNK_TARGET_MS / p50).round().max(1.0) as usize;
    // Never plan fewer chunks than workers — that just idles them.
    let new_rows = scaled.min((m_rows / opts.workers.max(1)).max(1));
    if new_rows < cur_rows.saturating_mul(2) && new_rows.saturating_mul(2) > cur_rows {
        return;
    }
    LOG.info(&format!(
        "adaptive chunks: p50 {p50:.0}ms at ~{cur_rows} rows/chunk -> {new_rows} rows/chunk"
    ));
    ctx.sched.chunk_rows = new_rows;
}

/// The randomized route: sketch, recover, complete (+ power iterations).
///
/// The final `W = AᵀU₀` reduction goes through [`Executor::run_wpass`]
/// rather than a star fold: the completion `(Σ, P)` comes out of a banded
/// TSQR R-factor fold and `V` lands as staged row shards — in cluster
/// mode the leader never materializes the n-sized `W` or `V`.
fn randomized_passes(
    exec: &mut dyn Executor,
    ctx: &mut PassContext,
    opts: &SvdOptions,
    m_rows: usize,
    report: &mut PhaseReport,
) -> Result<RouteOutput> {
    let kp = ctx.kp;
    let mut omega: Option<Matrix> = None;
    let mut shards_count = 0usize;
    let mut iteration = 0usize;
    let m_mat = loop {
        // Each power-iteration round rewrites Y/U0 with new content; a
        // fresh shard epoch gives it a fresh namespace so a straggling
        // speculative write from the previous round cannot clobber it.
        ctx.shard_epoch = iteration as u32;
        // ---- pass 1: Y = A Ω, G = YᵀY ------------------------------------
        let t0 = Instant::now();
        let out = exec.run_pass(ctx, &Pass::ProjectGram { omega: omega.as_ref() })?;
        if out.rows as usize != m_rows {
            return Err(Error::Other(format!(
                "pass1 saw {} rows, expected {m_rows}",
                out.rows
            )));
        }
        shards_count = out.shards;
        let g = out
            .partial
            .ok_or_else(|| Error::Other("pass1 returned no gram partial".into()))?;
        report.push(&format!("pass1.project_gram[{iteration}]"), t0.elapsed(), out.rows, 0);

        // ---- leader: eigh(G), M = V_y Σ_y⁻¹ ------------------------------
        let t0 = Instant::now();
        let (w_eig, v_y) = ctx.backend.eigh(&g)?;
        let sig_y: Vec<f64> = w_eig.iter().map(|&w| w.max(0.0).sqrt()).collect();
        let inv_y = guarded_inverse(&sig_y, opts.sigma_cutoff_rel);
        let m_mat = v_y.scale_cols(&inv_y)?;
        report.push(&format!("leader.eigh_y[{iteration}]"), t0.elapsed(), kp as u64, 0);

        if iteration >= opts.power_iters {
            // The final recovery pass runs through `run_wpass` below, so
            // this round's M leaves the loop as the completion operand.
            break m_mat;
        }

        // ---- power round pass 2: U0 = Y M, W = Aᵀ U0 ---------------------
        // Consumed leader-side immediately as the next Ω, so it rides the
        // plain (star-transport) pass even in tree mode.
        let t0 = Instant::now();
        let out2 = exec.run_pass(ctx, &Pass::UrecoverTmul { m: &m_mat })?;
        let w_mat = out2
            .partial
            .ok_or_else(|| Error::Other("pass2 returned no W partial".into()))?;
        report.push(&format!("pass2.urecover_tmul[{iteration}]"), t0.elapsed(), out2.rows, 0);

        // ---- power iteration: Ω ← orth(W), repeat ------------------------
        let t0 = Instant::now();
        let (q, _) = crate::linalg::thin_qr(&w_mat)?;
        omega = Some(q);
        iteration += 1;
        report.push(&format!("leader.power_orth[{iteration}]"), t0.elapsed(), 0, 0);
        // The finished round's sketch shards are dead once its recovery
        // pass completed; drop them before the next round writes its own
        // namespace, so power iterations don't multiply peak temp disk.
        // (A straggling speculative duplicate may re-publish one later —
        // it is never read again, just bounded litter.)
        let done_epoch = (iteration - 1) as u32;
        for base in ["Y", "U0"] {
            let stale = ShardSet::new(
                ctx.work_dir,
                &crate::svd::executor::epoch_stem(base, done_epoch),
                ctx.shard_format,
            )?;
            stale.cleanup(shards_count);
        }
        // Round boundary: the next round re-plans its own shard fan-out
        // from scratch, so the chunk plan is free to change here.
        adapt_chunk_rows(ctx, opts, &out2.stats, m_rows);
    };

    // ---- final pass 2 + completion: reduce W, SVD its R, stage V ---------
    let t0 = Instant::now();
    let k = opts.k.min(kp);
    let wout = exec.run_wpass(ctx, &m_mat, k, COMPLETION_CUTOFF_REL, opts.compute_v)?;
    if wout.rows as usize != m_rows {
        return Err(Error::Other(format!(
            "pass2 saw {} rows, expected {m_rows}",
            wout.rows
        )));
    }
    let sigma: Vec<f64> = wout.sigma_full[..k].to_vec();
    let p_k = wout.p.slice_cols(0, k); // kp x k rotation
    report.push("pass2.wreduce_complete", t0.elapsed(), wout.rows, 0);

    // V: already on disk as staged row shards; pull a dense copy into the
    // result only when materialization is on (the default).
    let (v, v_shards, v_bands) = if opts.compute_v && wout.v_bands > 0 {
        let set = ShardSet::new(ctx.work_dir, "V", ctx.shard_format)?;
        let v = if opts.materialize_v { Some(set.merge_to_matrix(wout.v_bands)?) } else { None };
        (v, Some(set), wout.v_bands)
    } else {
        (None, None, 0)
    };

    // ---- pass 3: U = U0 P_k (rotate shards) ------------------------------
    let t0 = Instant::now();
    let out3 = exec.run_pass(ctx, &Pass::RotateU { p: &p_k })?;
    report.push("pass3.rotate_u", t0.elapsed(), out3.rows, 0);

    Ok(RouteOutput { k, sigma, v, shards: shards_count, v_shards, v_bands })
}

/// The paper's small-n exact route (§2.0.1): eigendecompose `AᵀA` directly,
/// then stream `U = A V Σ⁻¹`. V falls out of the eigensolve for free here,
/// so it is always returned densely — `compute_v` and the banded V shards
/// only buy anything on the randomized route.
fn gram_passes(
    exec: &mut dyn Executor,
    ctx: &PassContext,
    m_rows: usize,
    report: &mut PhaseReport,
) -> Result<RouteOutput> {
    let k = ctx.kp; // for this route kp = k.min(n).min(m)

    // ---- pass 1: G = AᵀA --------------------------------------------------
    let t0 = Instant::now();
    let out = exec.run_pass(ctx, &Pass::Ata)?;
    if out.rows as usize != m_rows {
        return Err(Error::Other(format!(
            "pass1 saw {} rows, expected {m_rows}",
            out.rows
        )));
    }
    let g = out
        .partial
        .ok_or_else(|| Error::Other("ata pass returned no partial".into()))?;
    report.push("pass1.ata", t0.elapsed(), out.rows, 0);

    // ---- leader: eigh(G) = V Σ² Vᵀ -----------------------------------------
    let t0 = Instant::now();
    let (w_eig, v_full) = ctx.backend.eigh(&g)?;
    let sigma_full: Vec<f64> = w_eig.iter().map(|&w| w.max(0.0).sqrt()).collect();
    let sigma: Vec<f64> = sigma_full[..k].to_vec();
    let v_k = v_full.slice_cols(0, k);
    let inv_s = guarded_inverse(&sigma, COMPLETION_CUTOFF_REL);
    // M = V_k Σ⁻¹ : the paper's U = A V Σ⁻¹ per-block multiplier.
    let m_mat = v_k.scale_cols(&inv_s)?;
    report.push("leader.eigh", t0.elapsed(), ctx.n as u64, 0);

    // ---- pass 2: U = A M ----------------------------------------------------
    let t0 = Instant::now();
    let out2 = exec.run_pass(ctx, &Pass::Mult { m: &m_mat })?;
    report.push("pass2.u_recover", t0.elapsed(), out2.rows, 0);

    Ok(RouteOutput {
        k,
        sigma,
        v: Some(v_k),
        shards: out2.shards,
        v_shards: None,
        v_bands: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::dataset::{gen_exact, Spectrum};
    use crate::svd::Svd;

    fn setup(name: &str, m: usize, n: usize, rank: usize, noise: f64) -> (InputSpec, Matrix, Vec<f64>) {
        let dir = std::env::temp_dir().join("tallfat_test_svd").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (a, sigma) = gen_exact(
            m,
            n,
            rank,
            Spectrum::Geometric { scale: 10.0, decay: 0.6 },
            noise,
            42,
        )
        .unwrap();
        let spec = InputSpec::csv(dir.join("A.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        (spec, a, sigma)
    }

    fn work(name: &str) -> String {
        std::env::temp_dir()
            .join("tallfat_test_svd")
            .join(name)
            .join("work")
            .to_string_lossy()
            .into_owned()
    }

    fn builder<'a>(spec: &InputSpec, name: &str, k: usize) -> Svd<'a> {
        Svd::over(spec)
            .unwrap()
            .rank(k)
            .oversample(8)
            .workers(3)
            .block(32)
            .work_dir(work(name))
    }

    #[test]
    fn randomized_recovers_low_rank_exactly() {
        let (spec, a, sigma_true) = setup("rand_exact", 300, 24, 6, 0.0);
        let r = builder(&spec, "rand_exact", 8).run().unwrap();
        assert_eq!(r.k, 8);
        for i in 0..6 {
            assert!(
                (r.sigma[i] - sigma_true[i]).abs() < 1e-6 * sigma_true[0],
                "sigma[{i}]: {} vs {}",
                r.sigma[i],
                sigma_true[i]
            );
        }
        // Reconstruction: rank-6 matrix from rank-8 factorization is exact.
        let recon = r.reconstruct().unwrap();
        let rel = recon.max_abs_diff(&a) / a.max_abs();
        assert!(rel < 1e-6, "rel {rel}");
    }

    #[test]
    fn randomized_with_noise_close_to_exact() {
        let (spec, a, _) = setup("rand_noise", 400, 32, 8, 0.01);
        let r = builder(&spec, "rand_noise", 8).run().unwrap();
        let exact = crate::linalg::exact_svd(&a).unwrap();
        for i in 0..4 {
            let rel = (r.sigma[i] - exact.sigma[i]).abs() / exact.sigma[i];
            assert!(rel < 0.05, "sigma[{i}] rel err {rel}");
        }
    }

    #[test]
    fn power_iterations_improve_slow_decay() {
        let dir = std::env::temp_dir().join("tallfat_test_svd").join("power");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (a, _) = gen_exact(300, 40, 40, Spectrum::Power { scale: 10.0 }, 0.0, 7).unwrap();
        let spec = InputSpec::csv(dir.join("A.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        let exact = crate::linalg::exact_svd(&a).unwrap();

        let run = |q: usize, name: &str| {
            let r = builder(&spec, name, 8)
                .oversample(4)
                .power_iters(q)
                .run()
                .unwrap();
            let recon = r.reconstruct().unwrap();
            let mut diff = 0.0f64;
            for i in 0..300 {
                for j in 0..40 {
                    diff += (recon.get(i, j) - a.get(i, j)).powi(2);
                }
            }
            diff.sqrt()
        };
        let err0 = run(0, "power0");
        let err2 = run(2, "power2");
        let tail: f64 = exact.sigma[8..].iter().map(|s| s * s).sum::<f64>().sqrt();
        assert!(err2 < err0 * 1.001, "q=2 ({err2}) should not be worse than q=0 ({err0})");
        assert!(err2 < 1.25 * tail, "q=2 err {err2} vs tail {tail}");
    }

    #[test]
    fn gram_route_matches_exact() {
        let (spec, a, _) = setup("gram", 200, 16, 16, 0.005);
        let r = builder(&spec, "gram", 16).exact_gram(true).run().unwrap();
        let exact = crate::linalg::exact_svd(&a).unwrap();
        for i in 0..16 {
            let denom = exact.sigma[i].max(1e-9);
            assert!(
                (r.sigma[i] - exact.sigma[i]).abs() / denom < 1e-3,
                "sigma[{i}]: {} vs {}",
                r.sigma[i],
                exact.sigma[i]
            );
        }
        let recon = r.reconstruct().unwrap();
        assert!(recon.max_abs_diff(&a) < 1e-6 * a.max_abs().max(1.0));
    }

    #[test]
    fn worker_count_does_not_change_result() {
        let (spec, _, _) = setup("workers", 150, 12, 5, 0.0);
        let run = |w: usize, name: &str| {
            builder(&spec, name, 6).workers(w).run().unwrap().sigma
        };
        let s1 = run(1, "w1");
        let s4 = run(4, "w4");
        for (a, b) in s1.iter().zip(s4.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn guarded_inverse_zeroes_small_tail() {
        let inv = guarded_inverse(&[4.0, 2.0, 4.0e-9], 1e-7);
        assert_eq!(inv[0], 0.25);
        assert_eq!(inv[1], 0.5);
        assert_eq!(inv[2], 0.0);
        assert!(guarded_inverse(&[], 1e-7).is_empty());
    }

    #[test]
    fn checked_dims_rejects_empty() {
        let dir = std::env::temp_dir().join("tallfat_test_svd").join("dims");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.csv").to_string_lossy().into_owned();
        std::fs::write(&path, "").unwrap();
        assert!(checked_dims(&InputSpec::csv(path)).is_err());
        assert!(checked_dims(&InputSpec::csv("/nonexistent/a.csv")).is_err());
    }
}
