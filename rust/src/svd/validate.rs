//! Accuracy metrics for SVD results (used by E4/E6 and the examples).

use crate::error::Result;
use crate::io::writer::ShardSet;
use crate::io::InputSpec;
use crate::linalg::{matmul, Matrix};
use crate::splitproc::{self, RowJob, SparseRowJob};
use crate::svd::result::SvdResult;

/// Streaming relative Frobenius reconstruction error
/// `||A - U Σ Vᵀ||_F / ||A||_F` without materializing A or U.
///
/// Worker `i` re-reads its chunk of A while streaming its own U shard (row
/// alignment as in pass 2). For PCA-mode results (`result.means` set) the
/// comparison is against the centered matrix `A - 1 meansᵀ` — the thing
/// the factorization actually approximates.
pub fn reconstruction_error_streaming(input: &InputSpec, result: &SvdResult) -> Result<f64> {
    let v = result
        .v
        .as_ref()
        .ok_or_else(|| crate::error::Error::Other("V not computed".into()))?;
    // B = Σ Vᵀ (k x n), so the per-row residual is a - u_row B.
    let b = {
        let mut b = v.t();
        for (i, s) in result.sigma.iter().enumerate() {
            for j in 0..b.cols() {
                let val = b.get(i, j) * s;
                b.set(i, j, val);
            }
        }
        b
    };

    struct ErrJob<'a> {
        u_reader: crate::io::writer::ShardReader,
        b: &'a Matrix,
        means: Option<&'a [f64]>,
        u_row: Vec<f64>,
        err2: f64,
        norm2: f64,
    }

    impl RowJob for ErrJob<'_> {
        fn exec_row(&mut self, a_row: &[f64]) -> Result<()> {
            if !self.u_reader.next_row(&mut self.u_row)? {
                return Err(crate::error::Error::Other("U shard exhausted".into()));
            }
            let k = self.u_row.len();
            for (j, &raw) in a_row.iter().enumerate() {
                let aij = match self.means {
                    Some(m) => raw - m[j],
                    None => raw,
                };
                let mut recon = 0.0;
                for t in 0..k {
                    recon += self.u_row[t] * self.b.get(t, j);
                }
                self.err2 += (aij - recon) * (aij - recon);
                self.norm2 += aij * aij;
            }
            Ok(())
        }
    }

    /// Sparse sibling of `ErrJob`: scatter each sparse row against the
    /// (dense) reconstruction without materializing it.
    struct SparseErrJob<'a> {
        u_reader: crate::io::writer::ShardReader,
        b: &'a Matrix,
        means: Option<&'a [f64]>,
        u_row: Vec<f64>,
        err2: f64,
        norm2: f64,
    }

    impl SparseRowJob for SparseErrJob<'_> {
        fn exec_row(&mut self, indices: &[u32], values: &[f64]) -> Result<()> {
            if !self.u_reader.next_row(&mut self.u_row)? {
                return Err(crate::error::Error::Other("U shard exhausted".into()));
            }
            let k = self.u_row.len();
            let n = self.b.cols();
            let mut next = 0usize; // cursor into the ascending sparse indices
            for j in 0..n {
                let raw = if next < indices.len() && indices[next] as usize == j {
                    let v = values[next];
                    next += 1;
                    v
                } else {
                    0.0
                };
                let aij = match self.means {
                    Some(m) => raw - m[j],
                    None => raw,
                };
                let mut recon = 0.0;
                for t in 0..k {
                    recon += self.u_row[t] * self.b.get(t, j);
                }
                self.err2 += (aij - recon) * (aij - recon);
                self.norm2 += aij * aij;
            }
            Ok(())
        }
    }

    let u_shards = &result.u_shards;
    let b_ref = &b;
    let means_ref = result.means.as_deref();
    let (err2, norm2) = if input.format.is_sparse() {
        let results = splitproc::run_chunked(input, result.shards, |chunk| {
            let mut job = SparseErrJob {
                u_reader: u_shards.open_reader(chunk.index)?,
                b: b_ref,
                means: means_ref,
                u_row: Vec::new(),
                err2: 0.0,
                norm2: 0.0,
            };
            splitproc::run_chunk_sparse(input, chunk, &mut job)?;
            Ok((job.err2, job.norm2))
        })?;
        results.iter().fold((0.0, 0.0), |(e, n), &(je, jn)| (e + je, n + jn))
    } else {
        let results = splitproc::run(input, result.shards, |chunk| {
            Ok(ErrJob {
                u_reader: u_shards.open_reader(chunk.index)?,
                b: b_ref,
                means: means_ref,
                u_row: Vec::new(),
                err2: 0.0,
                norm2: 0.0,
            })
        })?;
        let e: f64 = results.iter().map(|r| r.job.err2).sum();
        let n: f64 = results.iter().map(|r| r.job.norm2).sum();
        (e, n)
    };
    Ok((err2 / norm2.max(1e-300)).sqrt())
}

/// `max |UᵀU - I|` computed by streaming the U shards (Gram accumulation).
pub fn u_orthonormality_residual(shards: &ShardSet, n_shards: usize, k: usize) -> Result<f64> {
    let mut g = Matrix::zeros(k, k);
    let mut row = Vec::new();
    for i in 0..n_shards {
        let mut r = shards.open_reader(i)?;
        while r.next_row(&mut row)? {
            crate::linalg::ops::outer_accumulate(&mut g, &row);
        }
    }
    Ok(g.max_abs_diff(&Matrix::eye(k)))
}

/// Relative per-value error of computed vs reference singular values.
pub fn sigma_relative_errors(got: &[f64], want: &[f64]) -> Vec<f64> {
    got.iter()
        .zip(want.iter())
        .map(|(g, w)| (g - w).abs() / w.abs().max(1e-300))
        .collect()
}

/// Pairwise-distance distortion of a projection (the JL check, E4):
/// samples `pairs` row pairs from A (in memory) and its projection Y and
/// returns `(mean |ratio - 1|, max |ratio - 1|)` over
/// `ratio = d_Y(i,j) / d_A(i,j)`.
pub fn distance_distortion(a: &Matrix, y: &Matrix, pairs: usize, seed: u64) -> (f64, f64) {
    use crate::rng::splitmix::mix3;
    let m = a.rows();
    assert_eq!(m, y.rows());
    let mut sum = 0.0f64;
    let mut max = 0.0f64;
    let mut counted = 0usize;
    let mut t = 0u64;
    while counted < pairs {
        let i = (mix3(seed, t, 0) % m as u64) as usize;
        let j = (mix3(seed, t, 1) % m as u64) as usize;
        t += 1;
        if i == j {
            continue;
        }
        let da: f64 = a
            .row(i)
            .iter()
            .zip(a.row(j))
            .map(|(x, z)| (x - z) * (x - z))
            .sum::<f64>()
            .sqrt();
        if da < 1e-12 {
            continue;
        }
        let dy: f64 = y
            .row(i)
            .iter()
            .zip(y.row(j))
            .map(|(x, z)| (x - z) * (x - z))
            .sum::<f64>()
            .sqrt();
        let dist = (dy / da - 1.0).abs();
        sum += dist;
        max = max.max(dist);
        counted += 1;
    }
    (sum / pairs as f64, max)
}

/// Dense (in-memory) rank-k reconstruction error helper for tests/benches.
pub fn dense_reconstruction_error(a: &Matrix, u: &Matrix, sigma: &[f64], v: &Matrix) -> Result<f64> {
    let us = u.scale_cols(sigma)?;
    let recon = matmul(&us, &v.t())?;
    let mut err2 = 0.0;
    let mut norm2 = 0.0;
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            err2 += (a.get(i, j) - recon.get(i, j)).powi(2);
            norm2 += a.get(i, j).powi(2);
        }
    }
    Ok((err2 / norm2.max(1e-300)).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::dataset::{gen_exact, Spectrum};
    use crate::svd::Svd;

    #[test]
    fn streaming_error_matches_dense() {
        let dir = std::env::temp_dir().join("tallfat_test_validate");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (a, _) = gen_exact(
            120,
            16,
            6,
            Spectrum::Geometric { scale: 4.0, decay: 0.7 },
            0.02,
            9,
        )
        .unwrap();
        let spec = InputSpec::csv(dir.join("A.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        let r = Svd::over(&spec)
            .unwrap()
            .rank(6)
            .oversample(6)
            .workers(2)
            .block(32)
            .work_dir(dir.join("work").to_string_lossy().into_owned())
            .run()
            .unwrap();
        let streaming = reconstruction_error_streaming(&spec, &r).unwrap();
        let dense = dense_reconstruction_error(
            &a,
            &r.u_matrix().unwrap(),
            &r.sigma,
            r.v.as_ref().unwrap(),
        )
        .unwrap();
        assert!((streaming - dense).abs() < 1e-10, "{streaming} vs {dense}");
        // U orthonormal
        let resid = u_orthonormality_residual(&r.u_shards, r.shards, r.k).unwrap();
        assert!(resid < 1e-6, "{resid}");
    }

    #[test]
    fn distortion_identity_projection_is_zero() {
        let (a, _) = gen_exact(40, 8, 8, Spectrum::Power { scale: 1.0 }, 0.0, 3).unwrap();
        let (mean, max) = distance_distortion(&a, &a, 50, 1);
        assert_eq!(mean, 0.0);
        assert_eq!(max, 0.0);
    }

    #[test]
    fn sigma_errors() {
        let e = sigma_relative_errors(&[1.1, 2.0], &[1.0, 2.0]);
        assert!((e[0] - 0.1).abs() < 1e-12);
        assert_eq!(e[1], 0.0);
    }
}
