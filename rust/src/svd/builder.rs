//! The fluent public entry point of the SVD pipeline.
//!
//! ```no_run
//! use tallfat::io::InputSpec;
//! use tallfat::svd::Svd;
//!
//! # fn main() -> tallfat::Result<()> {
//! let input = InputSpec::csv("/data/A.csv");
//! let result = Svd::over(&input)?   // validates the input up front
//!     .rank(16)
//!     .oversample(8)
//!     .power_iters(1)
//!     .center(true)                 // PCA mode
//!     .run()?;                      // LocalExecutor by default
//! println!("sigma[0] = {}", result.sigma[0]);
//! # Ok(())
//! # }
//! ```
//!
//! Swap the execution substrate without touching the math:
//!
//! ```ignore
//! let mut cluster = ClusterExecutor::accept("0.0.0.0:7070", 8)?;
//! let result = Svd::over(&input)?.rank(16).executor(&mut cluster).run()?;
//! cluster.shutdown()?;
//! ```

use crate::backend::native::NativeBackend;
use crate::backend::BackendRef;
use crate::config::{InputFormat, RunConfig};
use crate::error::Result;
use crate::io::InputSpec;
use crate::error::Error;
use crate::svd::executor::{Executor, LocalExecutor};
use crate::svd::pipeline::{checked_dims, run_svd, SvdOptions};
use crate::svd::result::SvdResult;
use crate::util::Logger;

static LOG: Logger = Logger::new("svd");

/// Builder for one SVD run: input and options accumulate fluently, `run()`
/// drives the executor-generic pipeline ([`crate::svd::pipeline`]).
pub struct Svd<'a> {
    input: InputSpec,
    dims: (usize, usize),
    opts: SvdOptions,
    backend: Option<BackendRef>,
    executor: Option<&'a mut dyn Executor>,
    save_model: Option<String>,
    cols: Option<usize>,
}

impl<'a> Svd<'a> {
    /// Start a run over `input`. Reads the dimensions eagerly so degenerate
    /// inputs (missing file, zero rows/cols) fail here, once, instead of in
    /// every driver.
    pub fn over(input: &InputSpec) -> Result<Self> {
        let dims = checked_dims(input)?;
        Ok(Svd {
            input: input.clone(),
            dims,
            opts: SvdOptions::default(),
            backend: None,
            executor: None,
            save_model: None,
            cols: None,
        })
    }

    /// Build from a [`RunConfig`] (defaults < config file < CLI), including
    /// the backend selection — the coordinator's entry point.
    pub fn from_config(cfg: &RunConfig) -> Result<Self> {
        cfg.validate()?;
        let input = InputSpec { path: cfg.input.clone(), format: cfg.format };
        let mut b = Self::over(&input)?;
        b.opts = cfg.svd_options();
        b.backend = Some(crate::backend::make_backend(cfg)?);
        if cfg.cols > 0 {
            b = b.cols(cfg.cols);
        }
        Ok(b)
    }

    /// Input dimensions `(rows, cols)` as validated by [`Svd::over`].
    pub fn dims(&self) -> (usize, usize) {
        self.dims
    }

    /// Target rank of the factorization.
    pub fn rank(mut self, k: usize) -> Self {
        self.opts.k = k;
        self
    }

    /// Oversampling columns added to the sketch (Halko's `p`).
    pub fn oversample(mut self, p: usize) -> Self {
        self.opts.oversample = p;
        self
    }

    /// Subspace-iteration count (0 = the paper's plain sketch).
    pub fn power_iters(mut self, q: usize) -> Self {
        self.opts.power_iters = q;
        self
    }

    /// Split-Process worker count (the default [`LocalExecutor`] fan-out).
    pub fn workers(mut self, w: usize) -> Self {
        self.opts.workers = w;
        self
    }

    /// Row-block size fed to the block backend.
    pub fn block(mut self, rows: usize) -> Self {
        self.opts.block = rows;
        self
    }

    /// PRNG seed for the virtual Ω.
    pub fn seed(mut self, seed: u64) -> Self {
        self.opts.seed = seed;
        self
    }

    /// Pin the column count. Sparse scans derive n from the max index
    /// actually seen, which undershoots when a batch omits the tail
    /// columns; pinning the base model's n keeps chained `update` batches
    /// dimension-compatible. For dense inputs the pin must match the
    /// scanned width exactly; for sparse inputs it must be ≥ the scanned
    /// width. Validated by [`Svd::run`].
    pub fn cols(mut self, n: usize) -> Self {
        self.cols = Some(n);
        self
    }

    /// Directory for Y/U shards and outputs.
    pub fn work_dir(mut self, dir: impl Into<String>) -> Self {
        self.opts.work_dir = dir.into();
        self
    }

    /// Compute right singular vectors V (default true).
    pub fn compute_v(mut self, yes: bool) -> Self {
        self.opts.compute_v = yes;
        self
    }

    /// Format of the Y/U0/U intermediate shards (default Bin).
    pub fn shard_format(mut self, format: InputFormat) -> Self {
        self.opts.shard_format = format;
        self
    }

    /// PCA mode: subtract per-column means before factorizing.
    pub fn center(mut self, yes: bool) -> Self {
        self.opts.center = yes;
        self
    }

    /// Skip the sketch: eigendecompose `AᵀA` directly (paper §2.0.1).
    pub fn exact_gram(mut self, yes: bool) -> Self {
        self.opts.exact_gram = yes;
        self
    }

    /// Relative cutoff for the sketch-stage guarded inverse (default
    /// [`crate::svd::DEFAULT_SIGMA_CUTOFF_REL`]).
    pub fn sigma_cutoff_rel(mut self, cutoff: f64) -> Self {
        self.opts.sigma_cutoff_rel = cutoff;
        self
    }

    /// Target relative residual (validated: must be positive and finite).
    /// The multi-pass routes work at the requested rank regardless; the
    /// adaptive streaming route ([`crate::stream::StreamSvd`]) widens its
    /// sketch until this target is met.
    pub fn tol(mut self, tol: f64) -> Self {
        self.opts.tol = tol;
        self
    }

    /// Cap scheduler chunks at `rows` rows each (0 = derive the chunk
    /// count from [`Svd::chunks_per_worker`] instead).
    pub fn chunk_rows(mut self, rows: usize) -> Self {
        self.opts.chunk_rows = rows;
        self
    }

    /// Chunks planned per worker (default
    /// [`crate::splitproc::sched::DEFAULT_CHUNKS_PER_WORKER`]; 1 =
    /// the old static one-chunk-per-worker schedule).
    pub fn chunks_per_worker(mut self, chunks: usize) -> Self {
        self.opts.chunks_per_worker = chunks;
        self
    }

    /// Retry budget per chunk before a pass fails (default
    /// [`crate::splitproc::sched::DEFAULT_CHUNK_RETRIES`]).
    pub fn chunk_retries(mut self, retries: usize) -> Self {
        self.opts.chunk_retries = retries;
        self
    }

    /// How chunk partials are reduced (default
    /// [`crate::svd::ReduceMode::Tree`] — the distributed pairwise merge
    /// schedule; `Star` restores the sequential leader-side fold).
    pub fn reduce(mut self, mode: crate::svd::ReduceMode) -> Self {
        self.opts.reduce = mode;
        self
    }

    /// Row-band height for the tall `W` reduction and the staged `V`
    /// shards (default 0 = auto-size from the sketch width).
    pub fn band_rows(mut self, rows: usize) -> Self {
        self.opts.band_rows = rows;
        self
    }

    /// Re-plan chunk granularity between passes from measured chunk wall
    /// times (default true; a nonzero [`Svd::chunk_rows`] always wins).
    pub fn adaptive_chunks(mut self, yes: bool) -> Self {
        self.opts.adaptive_chunks = yes;
        self
    }

    /// Materialize `V` densely on the leader (default true). Off, V is
    /// delivered only as staged row shards
    /// ([`crate::svd::SvdResult::v_shards`]) and the leader never holds an
    /// n-sized matrix.
    pub fn materialize_v(mut self, yes: bool) -> Self {
        self.opts.materialize_v = yes;
        self
    }

    /// Block-compute backend for leader math and (local) worker jobs.
    /// Defaults to the pure-rust native backend.
    pub fn backend(mut self, backend: BackendRef) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Execution substrate for the streaming passes. Defaults to a
    /// [`LocalExecutor`] with [`Svd::workers`] threads.
    pub fn executor(mut self, exec: &'a mut dyn Executor) -> Self {
        self.executor = Some(exec);
        self
    }

    /// After the run, persist the factors as a servable model directory
    /// (see [`crate::serve::store`]).
    pub fn save_model(mut self, dir: impl Into<String>) -> Self {
        self.save_model = Some(dir.into());
        self
    }

    /// Replace the whole option bag at once (escape hatch for callers that
    /// already hold an [`SvdOptions`]).
    pub fn options(mut self, opts: SvdOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Run the pipeline and, if requested, persist the model.
    pub fn run(self) -> Result<SvdResult> {
        let mut dims = self.dims;
        if let Some(n) = self.cols {
            if self.input.format.is_sparse() {
                if n < dims.1 {
                    return Err(Error::Config(format!(
                        "--cols {n} is below the input's max column index + 1 ({})",
                        dims.1
                    )));
                }
                dims.1 = n;
            } else if n != dims.1 {
                return Err(Error::Config(format!(
                    "--cols {n} disagrees with the dense input's width {}",
                    dims.1
                )));
            }
        }
        let backend = self
            .backend
            .unwrap_or_else(|| std::sync::Arc::new(NativeBackend::new()));
        let result = match self.executor {
            Some(exec) => run_svd(exec, &self.input, dims, backend, &self.opts)?,
            None => {
                let mut local = LocalExecutor::new(self.opts.workers);
                run_svd(&mut local, &self.input, dims, backend, &self.opts)?
            }
        };
        if let Some(dir) = &self.save_model {
            result.save_model(dir, Some(self.opts.seed))?;
            LOG.info(&format!(
                "model saved to {dir} (serve with `tallfat serve {dir}`)"
            ));
        }
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::dataset::{gen_exact, Spectrum};

    fn fixture(name: &str) -> (InputSpec, std::path::PathBuf) {
        let dir = std::env::temp_dir().join("tallfat_test_builder").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (a, _) = gen_exact(
            120,
            12,
            4,
            Spectrum::Geometric { scale: 6.0, decay: 0.6 },
            0.0,
            5,
        )
        .unwrap();
        let spec = InputSpec::csv(dir.join("a.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        (spec, dir)
    }

    #[test]
    fn over_rejects_missing_and_empty_inputs() {
        assert!(Svd::over(&InputSpec::csv("/nonexistent/a.csv")).is_err());
        let dir = std::env::temp_dir().join("tallfat_test_builder");
        std::fs::create_dir_all(&dir).unwrap();
        let empty = dir.join("empty.csv").to_string_lossy().into_owned();
        std::fs::write(&empty, "").unwrap();
        assert!(Svd::over(&InputSpec::csv(empty)).is_err());
    }

    #[test]
    fn builder_runs_with_default_backend_and_executor() {
        let (spec, dir) = fixture("defaults");
        let b = Svd::over(&spec).unwrap();
        assert_eq!(b.dims(), (120, 12));
        let r = b
            .rank(4)
            .oversample(4)
            .workers(2)
            .block(32)
            .seed(9)
            .work_dir(dir.join("work").to_string_lossy().into_owned())
            .run()
            .unwrap();
        assert_eq!(r.k, 4);
        assert_eq!(r.sigma.len(), 4);
        assert!(r.v.is_some());
    }

    #[test]
    fn from_config_maps_every_field() {
        let (spec, dir) = fixture("cfg");
        let cfg = RunConfig {
            input: spec.path.clone(),
            k: 3,
            workers: 2,
            block: 32,
            seed: 11,
            shard_format: InputFormat::Csv,
            sigma_cutoff_rel: 1e-6,
            work_dir: dir.join("cfg_work").to_string_lossy().into_owned(),
            ..RunConfig::default()
        };
        let b = Svd::from_config(&cfg).unwrap();
        assert_eq!(b.opts.k, 3);
        assert_eq!(b.opts.shard_format, InputFormat::Csv);
        assert!((b.opts.sigma_cutoff_rel - 1e-6).abs() < 1e-18);
        let r = b.run().unwrap();
        // Csv shard format produces .csv U shards.
        assert!(r.u_shards.shard_path(0).ends_with(".csv"));
        assert_eq!(r.k, 3);
    }

    #[test]
    fn from_config_rejects_invalid() {
        let cfg = RunConfig::default(); // no input
        assert!(Svd::from_config(&cfg).is_err());
    }

    #[test]
    fn run_rejects_bad_options_with_config_error() {
        let (spec, dir) = fixture("badopts");
        let work = dir.join("work").to_string_lossy().into_owned();
        // Zero block would otherwise panic inside a worker thread.
        let err = Svd::over(&spec).unwrap().block(0).work_dir(work.clone()).run();
        assert!(err.is_err());
        let err = Svd::over(&spec).unwrap().rank(0).work_dir(work.clone()).run();
        assert!(err.is_err());
        let err = Svd::over(&spec)
            .unwrap()
            .sigma_cutoff_rel(2.0)
            .work_dir(work)
            .run();
        assert!(err.is_err());
    }

    #[test]
    fn cols_pin_widens_sparse_and_rejects_dense_mismatch() {
        let dir = std::env::temp_dir().join("tallfat_test_builder").join("cols");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // 1-based libsvm rows whose max index (8) undershoots the intended
        // 12-column dictionary.
        let mut text = String::new();
        for i in 0..40 {
            let a = 1 + i % 8;
            let b = 1 + (i * 3) % 8;
            text.push_str(&format!("1 {a}:{}.5 {b}:{}.25\n", i % 7, i % 5));
        }
        let path = dir.join("a.libsvm").to_string_lossy().into_owned();
        std::fs::write(&path, text).unwrap();
        let spec = InputSpec { path, format: InputFormat::Libsvm };

        // Undershot pin rejected before any pass runs.
        let under = Svd::over(&spec)
            .unwrap()
            .cols(4)
            .rank(2)
            .workers(2)
            .block(32)
            .work_dir(dir.join("w0").to_string_lossy().into_owned())
            .run();
        assert!(under.is_err());

        // Pinned dictionary wins over the derived max index.
        let r = Svd::over(&spec)
            .unwrap()
            .cols(12)
            .rank(2)
            .workers(2)
            .block(32)
            .work_dir(dir.join("w1").to_string_lossy().into_owned())
            .run()
            .unwrap();
        assert_eq!(r.n, 12);
        assert_eq!(r.v.as_ref().unwrap().rows(), 12);

        // Dense inputs must match exactly.
        let (dense, ddir) = fixture("cols_dense");
        let err = Svd::over(&dense)
            .unwrap()
            .cols(13)
            .rank(2)
            .work_dir(ddir.join("w").to_string_lossy().into_owned())
            .run();
        assert!(err.is_err());
    }

    #[test]
    fn save_model_hook_persists() {
        let (spec, dir) = fixture("save");
        let model = dir.join("model").to_string_lossy().into_owned();
        let _ = Svd::over(&spec)
            .unwrap()
            .rank(3)
            .workers(2)
            .block(32)
            .work_dir(dir.join("work").to_string_lossy().into_owned())
            .save_model(model.clone())
            .run()
            .unwrap();
        let root = std::path::Path::new(&model);
        assert!(root.join("CURRENT").exists());
        assert!(root.join("gen-000000").join("model.manifest").exists());
    }
}
