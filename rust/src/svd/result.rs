//! SVD run results.

use crate::error::Result;
use crate::io::writer::ShardSet;
use crate::linalg::Matrix;
use crate::metrics::PhaseReport;

/// Output of a (randomized or exact-Gram) SVD run.
///
/// `U` is *sharded on disk* (it is `m x k` — tall); σ and V are small and
/// in memory.
pub struct SvdResult {
    /// Input dimensions.
    pub m: usize,
    pub n: usize,
    /// Effective rank computed (k after truncation).
    pub k: usize,
    /// Descending singular values (length k).
    pub sigma: Vec<f64>,
    /// Right singular vectors, `n x k` (None when `compute_v = false`, or
    /// when the run opted out of leader-side materialization — see
    /// [`SvdResult::v_shards`]).
    pub v: Option<Matrix>,
    /// Staged `V` row shards on disk (randomized route): the distributed
    /// reduce writes V band by band, so a run with `materialize_v = false`
    /// still delivers V without the leader ever holding an n-sized matrix.
    pub v_shards: Option<ShardSet>,
    /// Number of `V` row shards (band order = row order).
    pub v_bands: usize,
    /// U shards on disk (one per worker chunk, row order preserved).
    pub u_shards: ShardSet,
    /// Number of U shards.
    pub shards: usize,
    /// Column means subtracted before factorization (PCA mode), if any.
    /// The factorization is of `A - 1 means^T`.
    pub means: Option<Vec<f64>>,
    /// Phase timing of the run.
    pub report: PhaseReport,
}

impl SvdResult {
    /// Materialize U (only for small m — tests and examples).
    pub fn u_matrix(&self) -> Result<Matrix> {
        self.u_shards.merge_to_matrix(self.shards)
    }

    /// Persist as a servable model directory (see [`crate::serve::store`]):
    /// manifest + σ/V/means + re-sharded U + cosine row-norm sidecar.
    /// Pass the run's Ω seed for provenance if known.
    pub fn save_model(&self, dir: impl AsRef<std::path::Path>, seed: Option<u64>) -> Result<()> {
        crate::serve::store::save_model(self, dir, seed)
    }

    /// Dense right singular vectors: the in-memory `v` when materialized,
    /// otherwise merged from the staged `V` row shards.
    pub fn v_matrix(&self) -> Result<Matrix> {
        if let Some(v) = &self.v {
            return Ok(v.clone());
        }
        match &self.v_shards {
            Some(set) if self.v_bands > 0 => set.merge_to_matrix(self.v_bands),
            _ => Err(crate::error::Error::Other("V not computed".into())),
        }
    }

    /// `A_k = U diag(sigma) V^T` reconstruction (requires V; small m only).
    pub fn reconstruct(&self) -> Result<Matrix> {
        let v = self.v_matrix()?;
        let u = self.u_matrix()?;
        let us = u.scale_cols(&self.sigma)?;
        crate::linalg::matmul(&us, &v.t())
    }
}
