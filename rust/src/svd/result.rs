//! SVD run results.

use crate::error::Result;
use crate::io::writer::ShardSet;
use crate::linalg::Matrix;
use crate::metrics::PhaseReport;

/// Output of a (randomized or exact-Gram) SVD run.
///
/// `U` is *sharded on disk* (it is `m x k` — tall); σ and V are small and
/// in memory.
pub struct SvdResult {
    /// Input dimensions.
    pub m: usize,
    pub n: usize,
    /// Effective rank computed (k after truncation).
    pub k: usize,
    /// Descending singular values (length k).
    pub sigma: Vec<f64>,
    /// Right singular vectors, `n x k` (None when `compute_v = false`).
    pub v: Option<Matrix>,
    /// U shards on disk (one per worker chunk, row order preserved).
    pub u_shards: ShardSet,
    /// Number of U shards.
    pub shards: usize,
    /// Column means subtracted before factorization (PCA mode), if any.
    /// The factorization is of `A - 1 means^T`.
    pub means: Option<Vec<f64>>,
    /// Phase timing of the run.
    pub report: PhaseReport,
}

impl SvdResult {
    /// Materialize U (only for small m — tests and examples).
    pub fn u_matrix(&self) -> Result<Matrix> {
        self.u_shards.merge_to_matrix(self.shards)
    }

    /// Persist as a servable model directory (see [`crate::serve::store`]):
    /// manifest + σ/V/means + re-sharded U + cosine row-norm sidecar.
    /// Pass the run's Ω seed for provenance if known.
    pub fn save_model(&self, dir: impl AsRef<std::path::Path>, seed: Option<u64>) -> Result<()> {
        crate::serve::store::save_model(self, dir, seed)
    }

    /// `A_k = U diag(sigma) V^T` reconstruction (requires V; small m only).
    pub fn reconstruct(&self) -> Result<Matrix> {
        let v = self
            .v
            .as_ref()
            .ok_or_else(|| crate::error::Error::Other("V not computed".into()))?;
        let u = self.u_matrix()?;
        let us = u.scale_cols(&self.sigma)?;
        crate::linalg::matmul(&us, &v.t())
    }
}
