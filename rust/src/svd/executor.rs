//! The execution substrate of the SVD pipeline.
//!
//! The paper's algorithm is a fixed schedule of *streaming passes* over the
//! input (project+gram, U-recovery, rotation, …) interleaved with tiny
//! leader-side eigensolves. Where those passes run — threads over byte
//! chunks of a local file, or remote workers over a shared file server — is
//! an implementation detail the math never sees. [`Executor`] is that seam:
//!
//! * [`LocalExecutor`] runs each pass over [`crate::splitproc`] threads
//!   (the paper's Split-Process engine, in-process);
//! * [`crate::cluster::ClusterExecutor`] streams the same chunk tasks to
//!   remote workers over the leader/worker RPC.
//!
//! ## The pass contract is chunk-task streaming
//!
//! A pass is not "one send per worker": it is a *queue of chunk tasks*
//! planned much finer than the worker count
//! ([`crate::splitproc::plan_chunks_policy`], knobs on
//! [`PassContext::sched`]), acknowledged chunk by chunk:
//!
//! ```text
//! planned -> queued -> assigned -> done
//!               ^          |
//!               +- requeued + (chunk failed within retry budget,
//!                              or its runner died mid-chunk)
//! ```
//!
//! Every chunk execution lands in [`execute_pass_chunk`] — the single
//! definition of what each pass does to one chunk of rows; a remote worker
//! literally runs the same function the local threads do. Chunk partials
//! are reduced **in chunk order** whatever order executions complete in —
//! sequentially or over the canonical merge-round tree, per
//! [`PassContext::reduce`] — so both executors produce bitwise-identical
//! reductions, and shard writes are staged + atomically published, so a
//! retried or speculated chunk can never leave a torn shard.
//!
//! The final tall reduction (`W = AᵀU₀`) has its own entry point,
//! [`Executor::run_wpass`]: instead of handing back one n-sized partial it
//! folds row bands of `W` through TSQR R factors into the completion's
//! `(Σ, P)` and writes the `V` rows band-by-band as a staged shard set —
//! the contract that lets the cluster executor keep `W` distributed and
//! the leader at `O(k²·log workers)` state.

use crate::backend::BackendRef;
use crate::config::InputFormat;
use crate::coordinator::server::MetricsRegistry;
use crate::error::{Error, Result};
use crate::io::writer::ShardSet;
use crate::io::InputSpec;
use crate::jobs::{
    AtaBlockJob, ColStatsJob, MultJob, Pass2Job, ProjectGramJob, SparseAtaJob,
    SparseColStatsJob, SparseMultJob, SparsePass2Job, SparseProjectGramJob,
};
use crate::linalg::{matmul, Matrix};
use crate::rng::VirtualMatrix;
use crate::splitproc::{
    self, Blocked, CenteredJob, ChunkMeta, SchedPolicy, SchedStats, SparseBlocked,
};
use crate::svd::reduce::{self, ReduceMode};
use std::sync::Arc;

/// Everything a pass needs besides its operand: where the rows come from,
/// where shards go, and the small run-wide constants.
pub struct PassContext<'a> {
    /// The shared input file every chunk streams from.
    pub input: &'a InputSpec,
    /// Block-compute backend for the per-chunk jobs.
    pub backend: BackendRef,
    /// Directory for Y/U0/U shards (shared filesystem in cluster mode).
    pub work_dir: &'a str,
    /// Format of the intermediate shards.
    pub shard_format: InputFormat,
    /// Row-block size fed to the backend.
    pub block: usize,
    /// Sketch seed (Ω is regenerated from it — the virtual-B of §2.1).
    pub seed: u64,
    /// Input column count.
    pub n: usize,
    /// Sketch width `k + oversample` (ProjectGram's Ω column count).
    pub kp: usize,
    /// Column means to subtract on the fly (PCA mode); empty = disabled.
    pub means: Arc<Vec<f64>>,
    /// Chunk scheduling knobs (chunk granularity + retry budget).
    pub sched: SchedPolicy,
    /// Shard-namespace epoch for passes that are re-run with different
    /// content (power iterations rewrite Y/U0 each round). Distinct epochs
    /// use distinct shard names, so a straggling speculative write from a
    /// previous round can never clobber the current round's shards.
    pub shard_epoch: u32,
    /// How chunk partials are reduced: sequential leader-side fold
    /// ([`ReduceMode::Star`]) or the canonical pairwise merge tree
    /// ([`ReduceMode::Tree`], the default — distributed across workers in
    /// cluster mode).
    pub reduce: ReduceMode,
    /// Row-band height for the tall `W` reduction (0 = auto-sized from the
    /// sketch width, [`reduce::auto_band_rows`]).
    pub band_rows: usize,
}

/// One streaming pass of the pipeline, named after what it computes.
/// Operands are the *small* leader-side matrices — never row data.
#[derive(Clone, Copy)]
pub enum Pass<'a> {
    /// Pass 0 (PCA mode): per-column sums; the driver divides by the row
    /// count to get means.
    ColStats,
    /// Standalone / exact-Gram pass 1: additive `AᵀA` partial.
    Ata,
    /// Randomized pass 1: `Y = A Ω` to shards + additive `YᵀY` partial.
    /// `None` regenerates Ω from the seed; `Some` is a power-iteration
    /// override.
    ProjectGram { omega: Option<&'a Matrix> },
    /// Randomized pass 2: `U0 = Y M` to shards + additive `Aᵀ U0` partial.
    UrecoverTmul { m: &'a Matrix },
    /// Exact-Gram pass 2: `U = A M` straight to U shards.
    Mult { m: &'a Matrix },
    /// Pass 3: rotate `U = U0 P` shard by shard.
    RotateU { p: &'a Matrix },
}

impl Pass<'_> {
    /// Short name for logs and phase reports.
    pub fn name(&self) -> &'static str {
        match self {
            Pass::ColStats => "colstats",
            Pass::Ata => "ata",
            Pass::ProjectGram { .. } => "project_gram",
            Pass::UrecoverTmul { .. } => "urecover_tmul",
            Pass::Mult { .. } => "mult",
            Pass::RotateU { .. } => "rotate_u",
        }
    }
}

/// What a pass produced: streamed row count, the chunk/shard fan-out, the
/// reduced additive partial (when the pass has one), and how the chunks
/// were scheduled.
pub struct PassOutput {
    pub rows: u64,
    /// Number of chunks the input was split into (= shard count on disk).
    pub shards: usize,
    pub partial: Option<Matrix>,
    /// Chunk scheduling outcome (retries, speculation, skew).
    pub stats: SchedStats,
}

/// What the tall-`W` pass + completion produced: the full singular value
/// estimate `σ(W)`, the `k'×k'` rotation `P` (W's right singular vectors),
/// and — when V materialization is on — the staged `V` row shards already
/// on disk (`v_bands` of them, band order = row order).
pub struct WPassOutput {
    pub rows: u64,
    /// Chunk fan-out of the underlying streaming pass.
    pub shards: usize,
    /// Number of `V` row shards written (0 when V wasn't materialized).
    pub v_bands: usize,
    /// All `k'` singular values of `W` (the completion truncates to `k`).
    pub sigma_full: Vec<f64>,
    /// W's right singular vectors (`k'×k'`).
    pub p: Matrix,
    pub stats: SchedStats,
}

/// An execution substrate for streaming passes: plan the chunk tasks, feed
/// them through its work queue (retrying/re-running per the
/// [`PassContext::sched`] policy), reduce the additive partials in chunk
/// order, leave shards on disk.
pub trait Executor {
    /// Substrate name for logs ("local", "cluster", …).
    fn name(&self) -> &str;

    /// Run one pass over the whole input.
    fn run_pass(&mut self, ctx: &PassContext, pass: &Pass) -> Result<PassOutput>;

    /// Run the final `W = AᵀU₀` pass and its completion: reduce `W`, take
    /// its SVD via the banded TSQR R-factor fold (never gramming `W`),
    /// and — when `compute_v` — write `V = W · P_k Σ_k⁻¹` as staged row
    /// shards under `work_dir`. The default drives [`Executor::run_pass`]
    /// and completes from the fully-reduced partial; the cluster executor
    /// overrides it to keep `W` distributed across workers.
    fn run_wpass(
        &mut self,
        ctx: &PassContext,
        m: &Matrix,
        k: usize,
        cutoff_rel: f64,
        compute_v: bool,
    ) -> Result<WPassOutput> {
        let out = self.run_pass(ctx, &Pass::UrecoverTmul { m })?;
        complete_wpass_from_full(out, ctx, k, cutoff_rel, compute_v)
    }
}

/// Complete the `W` reduction from a fully-materialized `n×k'` partial:
/// band-split it, fold per-band TSQR R factors into the definitive R,
/// SVD that for `(Σ_full, P)`, and write the `V` bands as shards. The
/// arithmetic is identical band order to the cluster's distributed fold,
/// so local and cluster completions agree to machine precision.
pub(crate) fn complete_wpass_from_full(
    out: PassOutput,
    ctx: &PassContext,
    k: usize,
    cutoff_rel: f64,
    compute_v: bool,
) -> Result<WPassOutput> {
    let w = out
        .partial
        .ok_or_else(|| Error::Other("W pass produced no partial".into()))?;
    let band_rows =
        if ctx.band_rows == 0 { reduce::auto_band_rows(ctx.kp) } else { ctx.band_rows };
    let bands = reduce::band_ranges(w.rows(), band_rows);
    let rs: Result<Vec<Matrix>> = bands
        .iter()
        .map(|&(lo, hi)| reduce::band_r_factor(&w.slice_rows(lo, hi)))
        .collect();
    let r = reduce::fold_band_rs(ctx.kp, rs?)?;
    let (sigma_full, p) = reduce::completion_from_r(&r)?;
    let v_bands = if compute_v {
        let mv = reduce::completion_mv(&sigma_full, &p, k, cutoff_rel)?;
        let set = ShardSet::new(ctx.work_dir, "V", ctx.shard_format)?;
        for (b, &(lo, hi)) in bands.iter().enumerate() {
            let v = matmul(&w.slice_rows(lo, hi), &mv)?;
            let mut wr = set.open_writer(b, v.cols())?;
            for i in 0..v.rows() {
                wr.write_row(v.row(i))?;
            }
            wr.finish()?;
        }
        bands.len()
    } else {
        0
    };
    Ok(WPassOutput {
        rows: out.rows,
        shards: out.shards,
        v_bands,
        sigma_full,
        p,
        stats: out.stats,
    })
}

/// Publish one pass's scheduler outcome into the global registry — both
/// executors call this after every pass, and the coordinator prints the
/// totals in its run summary:
///
/// * `pass_chunks_total/retried/speculated` counters;
/// * every chunk duration observed into the `sched_chunk_ms{pass=...}`
///   histogram, so per-pass p50/p99 are scrapeable;
/// * `pass_skew_ms` gauge — the derived p99−p50 of the latest pass.
pub(crate) fn publish_sched_stats(pass_name: &str, stats: &SchedStats) {
    let reg = MetricsRegistry::global();
    reg.add("pass_chunks_total", stats.chunks as f64);
    reg.add("pass_chunks_retried", stats.retried as f64);
    reg.add("pass_chunks_speculated", stats.speculated as f64);
    let labels = [("pass", pass_name)];
    for &ms in &stats.chunk_ms {
        reg.observe_labeled("sched_chunk_ms", &labels, ms);
    }
    reg.set("pass_skew_ms", stats.skew_ms);
}

/// Shard stem for an epoch: epoch 0 keeps the bare stem (the common,
/// single-execution case), later power-iteration rounds get their own
/// namespace (`Y.q1-…`).
pub(crate) fn epoch_stem(base: &str, epoch: u32) -> String {
    if epoch == 0 {
        base.to_string()
    } else {
        format!("{base}.q{epoch}")
    }
}

/// Run one pass over *one chunk* — the single implementation of the pass
/// structure. [`LocalExecutor`] calls this per thread; a remote worker calls
/// it per assignment ([`crate::cluster::worker::execute_assignment`]).
///
/// Sparse inputs (libsvm / sparse-CSV / csr) dispatch to the CSR job
/// family — `O(nnz)` work and chunk memory, centering via rank-1
/// corrections instead of row densification ([`crate::jobs::sparse`]).
///
/// Returns `(rows_streamed, additive_partial)`.
pub fn execute_pass_chunk(
    ctx: &PassContext,
    pass: &Pass,
    chunk: &ChunkMeta,
) -> Result<(u64, Option<Matrix>)> {
    if ctx.input.format.is_sparse() {
        return execute_pass_chunk_sparse(ctx, pass, chunk);
    }
    match *pass {
        Pass::ColStats => {
            let mut job = ColStatsJob::new(ctx.n);
            let rows = splitproc::run_chunk(ctx.input, chunk, &mut job)?;
            // Additive encoding: per-column sums (1 x n). Welford runs
            // within the chunk; sums reduce commutatively across chunks.
            let mut sums = Matrix::zeros(1, ctx.n);
            let count = job.count() as f64;
            for (j, &mean) in job.means().iter().enumerate() {
                sums.set(0, j, mean * count);
            }
            Ok((rows, Some(sums)))
        }
        Pass::Ata => {
            let job = AtaBlockJob::new(ctx.backend.clone(), ctx.n);
            let mut job =
                CenteredJob::new(Blocked::new(job, ctx.block, ctx.n), ctx.means.clone());
            let rows = splitproc::run_chunk(ctx.input, chunk, &mut job)?;
            Ok((rows, Some(job.into_inner().into_inner().into_partial())))
        }
        Pass::ProjectGram { omega } => {
            let omega = match omega {
                Some(o) => o.clone(),
                None => VirtualMatrix::projection(ctx.seed, ctx.n, ctx.kp).materialize(),
            };
            let y_shards =
                ShardSet::new(ctx.work_dir, &epoch_stem("Y", ctx.shard_epoch), ctx.shard_format)?;
            let job = ProjectGramJob::new(ctx.backend.clone(), omega, &y_shards, chunk.index)?;
            let mut job =
                CenteredJob::new(Blocked::new(job, ctx.block, ctx.n), ctx.means.clone());
            let rows = splitproc::run_chunk(ctx.input, chunk, &mut job)?;
            Ok((rows, Some(job.into_inner().into_inner().into_gram_partial())))
        }
        Pass::UrecoverTmul { m } => {
            let y_shards =
                ShardSet::new(ctx.work_dir, &epoch_stem("Y", ctx.shard_epoch), ctx.shard_format)?;
            let u0_shards =
                ShardSet::new(ctx.work_dir, &epoch_stem("U0", ctx.shard_epoch), ctx.shard_format)?;
            let job = Pass2Job::new(
                ctx.backend.clone(),
                m.clone(),
                &y_shards,
                &u0_shards,
                chunk.index,
                ctx.n,
            )?;
            let mut job =
                CenteredJob::new(Blocked::new(job, ctx.block, ctx.n), ctx.means.clone());
            let rows = splitproc::run_chunk(ctx.input, chunk, &mut job)?;
            Ok((rows, Some(job.into_inner().into_inner().into_w_partial())))
        }
        Pass::Mult { m } => {
            let u_shards = ShardSet::new(ctx.work_dir, "U", ctx.shard_format)?;
            let job = MultJob::new(ctx.backend.clone(), m.clone(), &u_shards, chunk.index)?;
            let mut job =
                CenteredJob::new(Blocked::new(job, ctx.block, ctx.n), ctx.means.clone());
            let rows = splitproc::run_chunk(ctx.input, chunk, &mut job)?;
            Ok((rows, None))
        }
        Pass::RotateU { p } => {
            let u0_shards =
                ShardSet::new(ctx.work_dir, &epoch_stem("U0", ctx.shard_epoch), ctx.shard_format)?;
            let u_shards = ShardSet::new(ctx.work_dir, "U", ctx.shard_format)?;
            let rows = rotate_one_shard(&u0_shards, &u_shards, chunk.index, p, ctx.block)?;
            Ok((rows, None))
        }
    }
}

/// The CSR arm of [`execute_pass_chunk`]: same pass structure, sparse
/// streaming and kernels. Only the A-streaming passes differ — `RotateU`
/// reads the (dense) U0 shards, never the input, so it shares the dense
/// implementation.
fn execute_pass_chunk_sparse(
    ctx: &PassContext,
    pass: &Pass,
    chunk: &ChunkMeta,
) -> Result<(u64, Option<Matrix>)> {
    match *pass {
        Pass::ColStats => {
            let mut job = SparseColStatsJob::new(ctx.n);
            let rows = splitproc::run_chunk_sparse(ctx.input, chunk, &mut job)?;
            Ok((rows, Some(job.into_sums())))
        }
        Pass::Ata => {
            let job = SparseAtaJob::new(ctx.backend.clone(), ctx.n, ctx.means.clone());
            let mut job = SparseBlocked::new(job, ctx.block, ctx.n);
            let rows = splitproc::run_chunk_sparse(ctx.input, chunk, &mut job)?;
            Ok((rows, Some(job.into_inner().into_partial())))
        }
        Pass::ProjectGram { omega } => {
            let omega = match omega {
                Some(o) => o.clone(),
                None => VirtualMatrix::projection(ctx.seed, ctx.n, ctx.kp).materialize(),
            };
            let y_shards =
                ShardSet::new(ctx.work_dir, &epoch_stem("Y", ctx.shard_epoch), ctx.shard_format)?;
            let job = SparseProjectGramJob::new(
                ctx.backend.clone(),
                omega,
                &y_shards,
                chunk.index,
                &ctx.means,
            )?;
            let mut job = SparseBlocked::new(job, ctx.block, ctx.n);
            let rows = splitproc::run_chunk_sparse(ctx.input, chunk, &mut job)?;
            Ok((rows, Some(job.into_inner().into_gram_partial())))
        }
        Pass::UrecoverTmul { m } => {
            let y_shards =
                ShardSet::new(ctx.work_dir, &epoch_stem("Y", ctx.shard_epoch), ctx.shard_format)?;
            let u0_shards =
                ShardSet::new(ctx.work_dir, &epoch_stem("U0", ctx.shard_epoch), ctx.shard_format)?;
            let job = SparsePass2Job::new(
                ctx.backend.clone(),
                m.clone(),
                &y_shards,
                &u0_shards,
                chunk.index,
                ctx.n,
                ctx.means.clone(),
            )?;
            let mut job = SparseBlocked::new(job, ctx.block, ctx.n);
            let rows = splitproc::run_chunk_sparse(ctx.input, chunk, &mut job)?;
            Ok((rows, Some(job.into_inner().into_w_partial())))
        }
        Pass::Mult { m } => {
            let u_shards = ShardSet::new(ctx.work_dir, "U", ctx.shard_format)?;
            let job = SparseMultJob::new(
                ctx.backend.clone(),
                m.clone(),
                &u_shards,
                chunk.index,
                &ctx.means,
            )?;
            let mut job = SparseBlocked::new(job, ctx.block, ctx.n);
            let rows = splitproc::run_chunk_sparse(ctx.input, chunk, &mut job)?;
            Ok((rows, None))
        }
        Pass::RotateU { p } => {
            let u0_shards =
                ShardSet::new(ctx.work_dir, &epoch_stem("U0", ctx.shard_epoch), ctx.shard_format)?;
            let u_shards = ShardSet::new(ctx.work_dir, "U", ctx.shard_format)?;
            let rows = rotate_one_shard(&u0_shards, &u_shards, chunk.index, p, ctx.block)?;
            Ok((rows, None))
        }
    }
}

/// `U = U0 P` over one shard: stream `block`-row slabs through one matmul.
fn rotate_one_shard(
    src: &ShardSet,
    dst: &ShardSet,
    index: usize,
    p: &Matrix,
    block: usize,
) -> Result<u64> {
    let mut reader = src.open_reader(index)?;
    let mut writer = dst.open_writer(index, p.cols())?;
    let mut row = Vec::new();
    let mut buf: Vec<Vec<f64>> = Vec::with_capacity(block);
    let mut count = 0u64;
    loop {
        buf.clear();
        while buf.len() < block {
            if !reader.next_row(&mut row)? {
                break;
            }
            buf.push(row.clone());
        }
        if buf.is_empty() {
            break;
        }
        let u0 = Matrix::from_rows(&buf)?;
        let u = matmul(&u0, p)?;
        for r in 0..u.rows() {
            writer.write_row(u.row(r))?;
        }
        count += u.rows() as u64;
        if buf.len() < block {
            break;
        }
    }
    writer.finish()?;
    Ok(count)
}

/// In-process executor: a `workers`-thread pool pulling chunk tasks off
/// the shared queue (the paper's Split-Process deployment on a single
/// machine, dynamically scheduled).
pub struct LocalExecutor {
    workers: usize,
}

impl LocalExecutor {
    pub fn new(workers: usize) -> Self {
        LocalExecutor { workers: workers.max(1) }
    }
}

impl Executor for LocalExecutor {
    fn name(&self) -> &str {
        "local"
    }

    fn run_pass(&mut self, ctx: &PassContext, pass: &Pass) -> Result<PassOutput> {
        // Materialize a seed-derived Ω once per pass instead of once per
        // chunk (every chunk would regenerate identical bits anyway).
        let omega_store;
        let pass = match pass {
            Pass::ProjectGram { omega: None } => {
                omega_store = VirtualMatrix::projection(ctx.seed, ctx.n, ctx.kp).materialize();
                Pass::ProjectGram { omega: Some(&omega_store) }
            }
            p => *p,
        };
        // Phase span: chunk spans emitted by the pool threads parent here,
        // so the trace nests chunk ⊂ phase ⊂ run.
        let mut phase_span = crate::obs::trace::Span::child(pass.name(), "phase");
        phase_span.arg_str("executor", "local");
        let (outputs, stats) =
            splitproc::run_scheduled(ctx.input, self.workers, &ctx.sched, |chunk| {
                execute_pass_chunk(ctx, &pass, chunk)
            })?;
        if outputs.is_empty() {
            return Err(Error::Config("input has no rows to chunk".into()));
        }
        let shards = outputs.len();
        let mut rows = 0u64;
        let mut partials = Vec::with_capacity(shards);
        // `outputs` is in chunk order, so this reduction is deterministic
        // regardless of which thread finished which chunk when — and
        // matches the cluster executor's reduction bit for bit: both walk
        // the same chunk-ordered fold (star) or the same merge-round
        // schedule (tree) over the same leaves.
        for (r, partial) in outputs {
            rows += r;
            if let Some(p) = partial {
                if p.rows() > 0 {
                    partials.push(p);
                }
            }
        }
        let partial = if partials.is_empty() {
            None
        } else {
            Some(match ctx.reduce {
                ReduceMode::Star => splitproc::reduce_partials(partials)?,
                ReduceMode::Tree => reduce::tree_reduce(partials)?,
            })
        };
        phase_span.arg_num("chunks", stats.chunks as f64);
        publish_sched_stats(pass.name(), &stats);
        Ok(PassOutput { rows, shards, partial, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::io::dataset::{gen_exact, Spectrum};
    use crate::linalg::gram;

    fn ctx_fixture(name: &str) -> (InputSpec, Matrix, String) {
        let dir = std::env::temp_dir().join("tallfat_test_executor").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (a, _) = gen_exact(
            90,
            8,
            4,
            Spectrum::Geometric { scale: 5.0, decay: 0.7 },
            0.01,
            17,
        )
        .unwrap();
        let spec = InputSpec::csv(dir.join("a.csv").to_string_lossy().into_owned());
        crate::io::write_matrix(&a, &spec).unwrap();
        (spec, a, dir.join("work").to_string_lossy().into_owned())
    }

    fn ctx<'a>(input: &'a InputSpec, work: &'a str, n: usize) -> PassContext<'a> {
        PassContext {
            input,
            backend: std::sync::Arc::new(NativeBackend::new()),
            work_dir: work,
            shard_format: InputFormat::Bin,
            block: 16,
            seed: 3,
            n,
            kp: 4,
            means: Arc::new(Vec::new()),
            sched: SchedPolicy::default(),
            shard_epoch: 0,
            reduce: ReduceMode::Tree,
            band_rows: 0,
        }
    }

    #[test]
    fn local_ata_pass_matches_dense_gram() {
        let (input, a, work) = ctx_fixture("ata");
        let mut exec = LocalExecutor::new(3);
        let out = exec.run_pass(&ctx(&input, &work, 8), &Pass::Ata).unwrap();
        assert_eq!(out.rows, 90);
        assert!(out.shards >= 1);
        let g = out.partial.unwrap();
        assert!(g.max_abs_diff(&gram(&a)) < 1e-9);
    }

    #[test]
    fn local_colstats_pass_sums_columns() {
        let (input, a, work) = ctx_fixture("colstats");
        let mut exec = LocalExecutor::new(2);
        let out = exec.run_pass(&ctx(&input, &work, 8), &Pass::ColStats).unwrap();
        let sums = out.partial.unwrap();
        assert_eq!(sums.shape(), (1, 8));
        for j in 0..8 {
            let want: f64 = (0..a.rows()).map(|i| a.get(i, j)).sum();
            assert!((sums.get(0, j) - want).abs() < 1e-8, "col {j}");
        }
    }

    #[test]
    fn local_project_gram_writes_shards_and_partial() {
        let (input, _, work) = ctx_fixture("pg");
        let mut exec = LocalExecutor::new(2);
        let c = ctx(&input, &work, 8);
        let out = exec.run_pass(&c, &Pass::ProjectGram { omega: None }).unwrap();
        assert_eq!(out.rows, 90);
        let g = out.partial.unwrap();
        assert_eq!(g.shape(), (4, 4));
        // Y shards exist and hold all rows at sketch width.
        let y = ShardSet::new(&work, "Y", InputFormat::Bin).unwrap();
        let merged = y.merge_to_matrix(out.shards).unwrap();
        assert_eq!(merged.shape(), (90, 4));
        // Partial really is YᵀY.
        assert!(g.max_abs_diff(&gram(&merged)) < 1e-9);
    }

    #[test]
    fn sparse_input_passes_match_densified_input() {
        use crate::linalg::SparseMatrix;
        let dir = std::env::temp_dir().join("tallfat_test_executor").join("sparse");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // ~80% sparse fixture, including an all-zero row.
        let mut a = Matrix::zeros(70, 8);
        let g = crate::rng::Gaussian::new(9);
        for i in 0..70 {
            for j in 0..8 {
                if i != 10 && (i * 8 + j) % 5 == 0 {
                    a.set(i, j, g.sample(i as u64, j as u64));
                }
            }
        }
        let sparse = InputSpec::libsvm(dir.join("a.libsvm").to_string_lossy().into_owned());
        crate::io::sparse::write_sparse_matrix(
            &SparseMatrix::from_dense(&a, 0.0),
            &sparse.path,
            crate::config::InputFormat::Libsvm,
        )
        .unwrap();
        let work = dir.join("work").to_string_lossy().into_owned();
        let mut exec = LocalExecutor::new(3);
        // Ata parity
        let out = exec.run_pass(&ctx(&sparse, &work, 8), &Pass::Ata).unwrap();
        assert_eq!(out.rows, 70);
        assert!(out.partial.unwrap().max_abs_diff(&gram(&a)) < 1e-9);
        // ProjectGram writes the same Y shards a dense run would
        let c = ctx(&sparse, &work, 8);
        let out = exec.run_pass(&c, &Pass::ProjectGram { omega: None }).unwrap();
        assert_eq!(out.rows, 70);
        let y = ShardSet::new(&work, "Y", InputFormat::Bin).unwrap();
        let merged = y.merge_to_matrix(out.shards).unwrap();
        let omega = VirtualMatrix::projection(3, 8, 4).materialize();
        let want = matmul(&a, &omega).unwrap();
        assert!(merged.max_abs_diff(&want) < 1e-9);
        assert!(out.partial.unwrap().max_abs_diff(&gram(&want)) < 1e-9);
    }

    #[test]
    fn pass_names_are_stable() {
        let m = Matrix::zeros(1, 1);
        assert_eq!(Pass::ColStats.name(), "colstats");
        assert_eq!(Pass::ProjectGram { omega: None }.name(), "project_gram");
        assert_eq!(Pass::RotateU { p: &m }.name(), "rotate_u");
    }

    #[test]
    fn pass_plans_more_chunks_than_workers() {
        let (input, a, work) = ctx_fixture("finegrained");
        let mut exec = LocalExecutor::new(2);
        let mut c = ctx(&input, &work, 8);
        c.sched = SchedPolicy { chunks_per_worker: 4, ..SchedPolicy::default() };
        let out = exec.run_pass(&c, &Pass::Ata).unwrap();
        assert_eq!(out.rows, 90);
        assert!(out.shards > 2, "only {} chunks planned", out.shards);
        assert_eq!(out.stats.chunks, out.shards);
        assert!(out.partial.unwrap().max_abs_diff(&gram(&a)) < 1e-9);
    }

    #[test]
    fn epoch_stems_namespace_reruns() {
        assert_eq!(epoch_stem("Y", 0), "Y");
        assert_eq!(epoch_stem("Y", 2), "Y.q2");
        assert_eq!(epoch_stem("U0", 1), "U0.q1");
    }

    #[test]
    fn star_and_tree_reductions_agree_on_ata() {
        let (input, a, work) = ctx_fixture("reduce_modes");
        let mut exec = LocalExecutor::new(3);
        let mut c = ctx(&input, &work, 8);
        c.sched = SchedPolicy { chunks_per_worker: 3, ..SchedPolicy::default() };
        c.reduce = ReduceMode::Star;
        let star = exec.run_pass(&c, &Pass::Ata).unwrap().partial.unwrap();
        c.reduce = ReduceMode::Tree;
        let tree = exec.run_pass(&c, &Pass::Ata).unwrap().partial.unwrap();
        // Same leaves, different association: equal to float round-off.
        assert!(star.max_abs_diff(&tree) < 1e-12 * star.max_abs().max(1.0));
        assert!(star.max_abs_diff(&gram(&a)) < 1e-9);
    }

    #[test]
    fn local_wpass_banded_completion_matches_dense_w() {
        let (input, a, work) = ctx_fixture("wpass");
        let mut exec = LocalExecutor::new(2);
        let mut c = ctx(&input, &work, 8);
        c.band_rows = 3; // three bands of the 8-row W
        exec.run_pass(&c, &Pass::ProjectGram { omega: None }).unwrap();
        let m = Matrix::from_fn(4, 4, |i, j| if i == j { 1.0 } else { 0.0 });
        let out = exec.run_wpass(&c, &m, 2, 1e-12, true).unwrap();
        assert_eq!(out.rows, 90);
        assert_eq!(out.v_bands, 3);
        // Dense oracle: W = Aᵀ (Y · I) = Aᵀ Y.
        let omega = VirtualMatrix::projection(3, 8, 4).materialize();
        let y = matmul(&a, &omega).unwrap();
        let w = crate::linalg::matmul_tn(&a, &y).unwrap();
        let exact = crate::linalg::exact_svd(&w).unwrap();
        for i in 0..4 {
            assert!(
                (out.sigma_full[i] - exact.sigma[i]).abs() < 1e-9 * exact.sigma[0].max(1.0),
                "sigma[{i}]"
            );
        }
        // The staged V shards concatenate to W · P_k Σ_k⁻¹ = V_k (up to
        // per-column sign).
        let vset = ShardSet::new(&work, "V", InputFormat::Bin).unwrap();
        let v = vset.merge_to_matrix(out.v_bands).unwrap();
        assert_eq!(v.shape(), (8, 2));
        for j in 0..2 {
            let dot: f64 = (0..8).map(|i| v.get(i, j) * exact.v.get(i, j)).sum();
            let sign = if dot < 0.0 { -1.0 } else { 1.0 };
            for i in 0..8 {
                assert!(
                    (v.get(i, j) - sign * exact.v.get(i, j)).abs() < 1e-9,
                    "v[{i},{j}]"
                );
            }
        }
    }
}
