//! Map-Reduce baseline (paper §3, Figure 2).
//!
//! The paper motivates Split-Process by contrast with Map-Reduce: the
//! commutative-sum reductions here never need a shuffle, but a faithful MR
//! execution pays for one anyway. This module is a minimal but honest MR
//! engine — mappers spill hash-partitioned `(key, value)` pairs to disk,
//! reducers read+sort+group their partition — instrumented to report the
//! *bytes materialized* so E2 can quantify the overhead the paper hand-waves.
//!
//! Keys are `(u32, u32)` (matrix coordinates) and values `f64`, which covers
//! the linear-algebra jobs in the paper.

pub mod ata_mr;
pub mod engine;

pub use ata_mr::{ata_mapreduce, AtaMrMode};
pub use engine::{MapReduceEngine, MrStats};
