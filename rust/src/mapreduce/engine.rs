//! The MR engine: map → (hash-partitioned spill files) → sort/group → reduce.

use crate::error::{Error, Result};
use crate::io::InputSpec;
use crate::splitproc::{self, RowJob};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::PathBuf;

/// A `(key, value)` record: matrix coordinate + scalar.
pub type KV = ((u32, u32), f64);

const REC_BYTES: u64 = 16; // 4 + 4 + 8

/// Mapper context: emit pairs, they get hash-partitioned and spilled.
pub struct Emitter {
    writers: Vec<BufWriter<File>>,
    emitted: u64,
}

impl Emitter {
    fn new(dir: &PathBuf, mapper: usize, partitions: usize) -> Result<Self> {
        let writers = (0..partitions)
            .map(|p| {
                let path = dir.join(format!("map-{mapper}-part-{p}.bin"));
                Ok(BufWriter::with_capacity(1 << 18, File::create(path)?))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Emitter { writers, emitted: 0 })
    }

    /// Emit one pair (the mapper's output channel).
    pub fn emit(&mut self, key: (u32, u32), value: f64) -> Result<()> {
        let p = (key.0 as usize ^ (key.1 as usize).wrapping_mul(0x9E37)) % self.writers.len();
        let w = &mut self.writers[p];
        w.write_all(&key.0.to_le_bytes())?;
        w.write_all(&key.1.to_le_bytes())?;
        w.write_all(&value.to_le_bytes())?;
        self.emitted += 1;
        Ok(())
    }

    fn finish(mut self) -> Result<u64> {
        for w in &mut self.writers {
            w.flush()?;
        }
        Ok(self.emitted)
    }
}

/// Shuffle/scale accounting for one MR run (E2's measurable).
#[derive(Debug, Clone, Default)]
pub struct MrStats {
    pub mappers: usize,
    pub reducers: usize,
    pub pairs_emitted: u64,
    /// Bytes written to (and re-read from) the shuffle spill.
    pub shuffle_bytes: u64,
    pub reduce_groups: u64,
}

/// A minimal Map-Reduce engine over matrix-row inputs.
pub struct MapReduceEngine {
    dir: PathBuf,
    partitions: usize,
}

impl MapReduceEngine {
    pub fn new(work_dir: impl Into<PathBuf>, partitions: usize) -> Result<Self> {
        let dir = work_dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(MapReduceEngine { dir, partitions })
    }

    /// Run: `mapper(row, emitter)` over the input with `mappers` parallel
    /// map tasks (reusing the Split-Process chunker — the comparison is then
    /// purely about the shuffle), followed by sum-reduce per key.
    /// Returns the reduced pairs (sorted by key) and the run stats.
    pub fn run<M>(
        &self,
        input: &InputSpec,
        mappers: usize,
        mapper: M,
    ) -> Result<(Vec<KV>, MrStats)>
    where
        M: Fn(&[f64], &mut Emitter) -> Result<()> + Sync + Send,
    {
        // ---- map phase -----------------------------------------------------
        struct MapJob<'m, M> {
            emitter: Option<Emitter>,
            mapper: &'m M,
        }

        impl<M> RowJob for MapJob<'_, M>
        where
            M: Fn(&[f64], &mut Emitter) -> Result<()> + Sync + Send,
        {
            fn exec_row(&mut self, row: &[f64]) -> Result<()> {
                let em = self
                    .emitter
                    .as_mut()
                    .ok_or_else(|| Error::Other("emitter consumed".into()))?;
                (self.mapper)(row, em)
            }
        }

        let dir = &self.dir;
        let partitions = self.partitions;
        let mapper_ref = &mapper;
        let results = splitproc::run(input, mappers, |chunk| {
            Ok(MapJob {
                emitter: Some(Emitter::new(dir, chunk.index, partitions)?),
                mapper: mapper_ref,
            })
        })?;
        let actual_mappers = results.len();
        let mut pairs_emitted = 0u64;
        for mut r in results {
            pairs_emitted += r.job.emitter.take().unwrap().finish()?;
        }
        let shuffle_bytes = pairs_emitted * REC_BYTES;

        // ---- shuffle + reduce phase ----------------------------------------
        let reduce_outputs: Vec<Result<Vec<KV>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..partitions)
                .map(|p| {
                    let dir = dir.clone();
                    scope.spawn(move || -> Result<Vec<KV>> {
                        let mut records: Vec<KV> = Vec::new();
                        for m in 0..actual_mappers {
                            let path = dir.join(format!("map-{m}-part-{p}.bin"));
                            let mut r = BufReader::new(File::open(&path)?);
                            let mut buf = [0u8; REC_BYTES as usize];
                            loop {
                                match r.read_exact(&mut buf) {
                                    Ok(()) => {}
                                    Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
                                    Err(e) => return Err(e.into()),
                                }
                                let i = u32::from_le_bytes(buf[0..4].try_into().unwrap());
                                let j = u32::from_le_bytes(buf[4..8].try_into().unwrap());
                                let v = f64::from_le_bytes(buf[8..16].try_into().unwrap());
                                records.push(((i, j), v));
                            }
                        }
                        // the "sort" of sort-shuffle-reduce
                        records.sort_by_key(|(k, _)| *k);
                        // group + sum-reduce
                        let mut out: Vec<KV> = Vec::new();
                        for (k, v) in records {
                            match out.last_mut() {
                                Some((lk, lv)) if *lk == k => *lv += v,
                                _ => out.push((k, v)),
                            }
                        }
                        Ok(out)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(Error::Other("reducer panicked".into())))
                })
                .collect()
        });

        let mut all: Vec<KV> = Vec::new();
        for r in reduce_outputs {
            all.extend(r?);
        }
        all.sort_by_key(|(k, _)| *k);
        let stats = MrStats {
            mappers: actual_mappers,
            reducers: partitions,
            pairs_emitted,
            shuffle_bytes,
            reduce_groups: all.len() as u64,
        };

        // cleanup spills
        for m in 0..actual_mappers {
            for p in 0..partitions {
                let _ = std::fs::remove_file(dir.join(format!("map-{m}-part-{p}.bin")));
            }
        }
        Ok((all, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn input(name: &str, m: &Matrix) -> InputSpec {
        let dir = std::env::temp_dir().join("tallfat_test_mr");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name).to_string_lossy().into_owned();
        crate::io::csv::write_matrix_csv(m, &path).unwrap();
        InputSpec::csv(path)
    }

    #[test]
    fn word_count_style_sum() {
        // mapper: emit (col, 1.0) per nonzero — counts nonzeros per column.
        let m = Matrix::from_rows(&[
            vec![1.0, 0.0, 2.0],
            vec![0.0, 3.0, 4.0],
            vec![5.0, 0.0, 0.0],
        ])
        .unwrap();
        let spec = input("wc.csv", &m);
        let engine = MapReduceEngine::new(
            std::env::temp_dir().join("tallfat_test_mr").join("wc_work"),
            3,
        )
        .unwrap();
        let (pairs, stats) = engine
            .run(&spec, 2, |row, em| {
                for (j, &v) in row.iter().enumerate() {
                    if v != 0.0 {
                        em.emit((0, j as u32), 1.0)?;
                    }
                }
                Ok(())
            })
            .unwrap();
        assert_eq!(pairs, vec![((0, 0), 2.0), ((0, 1), 1.0), ((0, 2), 2.0)]);
        assert_eq!(stats.pairs_emitted, 5);
        assert_eq!(stats.shuffle_bytes, 5 * 16);
    }

    #[test]
    fn keys_aggregate_across_mappers() {
        let m = Matrix::from_fn(20, 1, |_i, _j| 1.0);
        let spec = input("agg.csv", &m);
        let engine = MapReduceEngine::new(
            std::env::temp_dir().join("tallfat_test_mr").join("agg_work"),
            2,
        )
        .unwrap();
        let (pairs, stats) = engine
            .run(&spec, 4, |_row, em| em.emit((7, 7), 1.0))
            .unwrap();
        assert_eq!(pairs, vec![((7, 7), 20.0)]);
        assert!(stats.mappers >= 1);
        assert_eq!(stats.reduce_groups, 1);
    }
}
