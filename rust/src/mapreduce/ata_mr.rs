//! `A^T A` expressed as a Map-Reduce job (E2's baseline).
//!
//! The paper's point (§3, Figure 2 vs Figure 3) is that a commutative sum
//! does not *need* a shuffle, yet a faithful Map-Reduce execution pays for
//! one. Here the same Gram computation runs through [`MapReduceEngine`]:
//! every row's outer product is emitted as `(i, j) -> A[r,i]*A[r,j]` pairs,
//! spilled to disk, sorted, grouped, and sum-reduced — so E2 can report the
//! exact bytes materialized where Split-Process materializes nothing.
//!
//! Two emission modes quantify how much a trivial optimization recovers:
//! * [`AtaMrMode::Full`] — all `n^2` pairs per row (the naive expression).
//! * [`AtaMrMode::Upper`] — only the upper triangle (`n(n+1)/2` per row),
//!   mirrored after the reduce. Still Θ(m·n²) shuffle traffic — the
//!   architectural gap to Split-Process's O(workers · n²) does not close.

use super::engine::{MapReduceEngine, MrStats};
use crate::error::{Error, Result};
use crate::io::InputSpec;
use crate::linalg::Matrix;
use std::path::PathBuf;

/// Pair-emission policy for the MR Gram job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AtaMrMode {
    /// Emit every `(i, j)` — the textbook formulation.
    Full,
    /// Emit `i <= j` only and mirror after reducing.
    Upper,
}

impl AtaMrMode {
    /// Pairs emitted per input row for an `n`-column matrix.
    pub fn pairs_per_row(self, n: usize) -> u64 {
        match self {
            AtaMrMode::Full => (n * n) as u64,
            AtaMrMode::Upper => (n * (n + 1) / 2) as u64,
        }
    }
}

/// Compute `A^T A` through the Map-Reduce engine.
///
/// `mappers` parallel map tasks (chunked exactly like Split-Process, so the
/// comparison isolates the shuffle), `partitions` reducers. Returns the
/// `n x n` Gram matrix and the shuffle accounting.
pub fn ata_mapreduce(
    input: &InputSpec,
    work_dir: impl Into<PathBuf>,
    mappers: usize,
    partitions: usize,
    mode: AtaMrMode,
) -> Result<(Matrix, MrStats)> {
    let (_, n) = input.dims()?;
    let engine = MapReduceEngine::new(work_dir, partitions)?;
    let (pairs, stats) = engine.run(input, mappers, move |row: &[f64], em| {
        if row.len() != n {
            return Err(Error::shape(format!(
                "ata_mapreduce: row has {} cols, expected {n}",
                row.len()
            )));
        }
        for i in 0..n {
            let lo = match mode {
                AtaMrMode::Full => 0,
                AtaMrMode::Upper => i,
            };
            for j in lo..n {
                em.emit((i as u32, j as u32), row[i] * row[j])?;
            }
        }
        Ok(())
    })?;

    let mut g = Matrix::zeros(n, n);
    for ((i, j), v) in pairs {
        let (i, j) = (i as usize, j as usize);
        if i >= n || j >= n {
            return Err(Error::shape(format!(
                "ata_mapreduce: reduced key ({i},{j}) outside {n}x{n}"
            )));
        }
        g.set(i, j, v);
        if mode == AtaMrMode::Upper && i != j {
            g.set(j, i, v);
        }
    }
    Ok((g, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::AtaRowJob;
    use crate::splitproc;

    fn fixture(name: &str, m: usize, n: usize) -> (InputSpec, Matrix) {
        let dir = std::env::temp_dir().join("tallfat_test_ata_mr");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name).to_string_lossy().into_owned();
        let a = Matrix::from_fn(m, n, |i, j| ((i * 7 + j * 3) % 11) as f64 - 5.0);
        crate::io::csv::write_matrix_csv(&a, &path).unwrap();
        (InputSpec::csv(path), a)
    }

    fn splitproc_gram(input: &InputSpec, n: usize) -> Matrix {
        let results = splitproc::run(input, 3, |_| Ok(AtaRowJob::new(n))).unwrap();
        splitproc::reduce_partials(results.into_iter().map(|r| r.job.into_partial()).collect())
            .unwrap()
    }

    #[test]
    fn full_mode_matches_splitproc() {
        let (spec, _) = fixture("full.csv", 23, 5);
        let want = splitproc_gram(&spec, 5);
        let dir = std::env::temp_dir().join("tallfat_test_ata_mr").join("w_full");
        let (got, stats) = ata_mapreduce(&spec, dir, 3, 2, AtaMrMode::Full).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-9);
        assert_eq!(stats.pairs_emitted, 23 * 25);
        assert_eq!(stats.shuffle_bytes, 23 * 25 * 16);
    }

    #[test]
    fn upper_mode_matches_and_halves_shuffle() {
        let (spec, _) = fixture("upper.csv", 17, 6);
        let want = splitproc_gram(&spec, 6);
        let dir = std::env::temp_dir().join("tallfat_test_ata_mr").join("w_upper");
        let (got, stats) = ata_mapreduce(&spec, dir, 2, 2, AtaMrMode::Upper).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-9);
        assert_eq!(stats.pairs_emitted, 17 * 21); // 6*7/2 per row
        assert!(stats.pairs_emitted < AtaMrMode::Full.pairs_per_row(6) * 17);
    }

    #[test]
    fn reduce_groups_equal_distinct_keys() {
        let (spec, _) = fixture("groups.csv", 9, 4);
        let dir = std::env::temp_dir().join("tallfat_test_ata_mr").join("w_groups");
        let (_, stats) = ata_mapreduce(&spec, dir, 2, 3, AtaMrMode::Full).unwrap();
        assert_eq!(stats.reduce_groups, 16);
    }

    #[test]
    fn single_mapper_single_reducer() {
        let (spec, _) = fixture("single.csv", 8, 3);
        let want = splitproc_gram(&spec, 3);
        let dir = std::env::temp_dir().join("tallfat_test_ata_mr").join("w_single");
        let (got, stats) = ata_mapreduce(&spec, dir, 1, 1, AtaMrMode::Full).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-9);
        assert_eq!(stats.mappers, 1);
        assert_eq!(stats.reducers, 1);
    }
}
