//! `serve-metrics`: a dependency-free HTTP endpoint exposing run metrics.
//!
//! The paper's cluster story needs the leader to be observable; this is
//! the minimal honest version — the shared event-driven connection runtime
//! ([`crate::net`]) serving the shared [`MetricsRegistry`] as Prometheus
//! text exposition. Every route answers *inline* on the event loop (a
//! metrics endpoint must stay scrapeable even when the process is busy).
//! Jobs publish into the registry; scrapers poll `GET /metrics`
//! (`GET /healthz` is the liveness probe; anything else is 404, non-GET
//! is 405).
//!
//! The registry holds two metric families:
//!
//! * **gauges/counters** — `set`/`add`/`get`, optionally with labels
//!   (`name{k="v"}`), rendered one line per labeled series under a
//!   `# TYPE ... gauge` header;
//! * **histograms** — `observe` records a value into log-spaced buckets
//!   (upper edges `0.001 · 2^i`, covering sub-microsecond to ~6 days in
//!   milliseconds), `quantile` reads p50/p99-style estimates back out by
//!   linear interpolation inside the winning bucket, and `render` emits
//!   the standard `_bucket{le="..."}`/`_sum`/`_count` exposition with
//!   cumulative bucket counts.
//!
//! Series identity is `(name, sorted labels)`, so label order at the call
//! site never splits a series. Label values are escaped per the Prometheus
//! text rules (`\\`, `\"`, `\n`).

use crate::error::Result;
use crate::net::http::{HttpRequest, HttpResponse};
use crate::net::{NetHandler, NetOptions, NetServer};
use crate::util::{Args, Logger};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

static LOG: Logger = Logger::new("metrics-server");

/// Number of finite histogram bucket edges (`0.001 · 2^i`, i in 0..N);
/// one more implicit `+Inf` bucket catches everything above the last edge.
pub const HISTOGRAM_BUCKETS: usize = 40;

/// Upper edge of finite bucket `i` (milliseconds in every current caller,
/// though the histogram itself is unit-agnostic).
pub fn bucket_edge(i: usize) -> f64 {
    1e-3 * 2f64.powi(i as i32)
}

/// All finite bucket upper edges, ascending — what `le=` labels render.
pub fn bucket_upper_edges() -> Vec<f64> {
    (0..HISTOGRAM_BUCKETS).map(bucket_edge).collect()
}

fn bucket_index(v: f64) -> usize {
    for i in 0..HISTOGRAM_BUCKETS {
        if v <= bucket_edge(i) {
            return i;
        }
    }
    HISTOGRAM_BUCKETS // +Inf bucket
}

/// Escape a label value for the text exposition: backslash, double quote,
/// and newline are the three characters the format reserves.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Sorted, owned label pairs — the canonical form a series is keyed by.
type Labels = Vec<(String, String)>;

fn owned_labels(labels: &[(&str, &str)]) -> Labels {
    let mut v: Labels =
        labels.iter().map(|(k, val)| (k.to_string(), val.to_string())).collect();
    v.sort();
    v
}

/// One metric series: name plus its sorted label set.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct Key {
    name: String,
    labels: Labels,
}

impl Key {
    fn new(name: &str, labels: &[(&str, &str)]) -> Key {
        Key { name: name.to_string(), labels: owned_labels(labels) }
    }

    /// `{k="v",...}` with an optional extra pair appended (the `le` label
    /// of a histogram bucket line); empty string when there are no labels.
    fn render_labels(&self, extra: Option<(&str, &str)>) -> String {
        if self.labels.is_empty() && extra.is_none() {
            return String::new();
        }
        let mut parts: Vec<String> = self
            .labels
            .iter()
            .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
            .collect();
        if let Some((k, v)) = extra {
            parts.push(format!("{k}=\"{}\"", escape_label(v)));
        }
        format!("{{{}}}", parts.join(","))
    }
}

/// Log-bucketed histogram: per-bucket counts, total sum and count.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>, // HISTOGRAM_BUCKETS finite buckets + 1 overflow
    sum: f64,
    count: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { counts: vec![0; HISTOGRAM_BUCKETS + 1], sum: 0.0, count: 0 }
    }
}

impl Histogram {
    fn observe(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let v = v.max(0.0);
        self.counts[bucket_index(v)] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Quantile estimate (`q` in [0, 1]): find the bucket where the
    /// cumulative count crosses `ceil(q · count)` and interpolate linearly
    /// inside it. `None` for an empty histogram. Observations past the last
    /// finite edge report that edge (the estimate saturates).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                if i == HISTOGRAM_BUCKETS {
                    return Some(bucket_edge(HISTOGRAM_BUCKETS - 1));
                }
                let hi = bucket_edge(i);
                let lo = if i == 0 { 0.0 } else { bucket_edge(i - 1) };
                let before = cum - c;
                let frac = (target - before) as f64 / c as f64;
                return Some(lo + frac * (hi - lo));
            }
        }
        None
    }

    /// Per-bucket counts (finite buckets then overflow), non-cumulative.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn count(&self) -> u64 {
        self.count
    }
}

/// Process-global metric registry (labeled gauges + histograms).
#[derive(Default)]
pub struct MetricsRegistry {
    values: Mutex<BTreeMap<Key, f64>>,
    histograms: Mutex<BTreeMap<Key, Histogram>>,
}

impl MetricsRegistry {
    /// The process-wide registry.
    pub fn global() -> &'static MetricsRegistry {
        static REG: OnceLock<MetricsRegistry> = OnceLock::new();
        REG.get_or_init(MetricsRegistry::default)
    }

    /// Set a gauge.
    pub fn set(&self, name: &str, value: f64) {
        self.set_labeled(name, &[], value);
    }

    /// Set a labeled gauge series (`name{k="v"}`).
    pub fn set_labeled(&self, name: &str, labels: &[(&str, &str)], value: f64) {
        crate::util::lock_unpoisoned(&self.values).insert(Key::new(name, labels), value);
    }

    /// Add to a counter (creates at 0).
    pub fn add(&self, name: &str, delta: f64) {
        self.add_labeled(name, &[], delta);
    }

    /// Add to a labeled counter series (creates at 0).
    pub fn add_labeled(&self, name: &str, labels: &[(&str, &str)], delta: f64) {
        *crate::util::lock_unpoisoned(&self.values)
            .entry(Key::new(name, labels))
            .or_insert(0.0) += delta;
    }

    /// Read one unlabeled metric.
    pub fn get(&self, name: &str) -> Option<f64> {
        self.get_labeled(name, &[])
    }

    /// Read one labeled series.
    pub fn get_labeled(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        crate::util::lock_unpoisoned(&self.values).get(&Key::new(name, labels)).copied()
    }

    /// Record a value into a histogram series.
    pub fn observe(&self, name: &str, value: f64) {
        self.observe_labeled(name, &[], value);
    }

    /// Record a value into a labeled histogram series.
    pub fn observe_labeled(&self, name: &str, labels: &[(&str, &str)], value: f64) {
        crate::util::lock_unpoisoned(&self.histograms)
            .entry(Key::new(name, labels))
            .or_default()
            .observe(value);
    }

    /// Quantile of an unlabeled histogram series (`None` if absent/empty).
    pub fn quantile(&self, name: &str, q: f64) -> Option<f64> {
        self.quantile_labeled(name, &[], q)
    }

    /// Quantile of a labeled histogram series (`None` if absent/empty).
    pub fn quantile_labeled(&self, name: &str, labels: &[(&str, &str)], q: f64) -> Option<f64> {
        crate::util::lock_unpoisoned(&self.histograms)
            .get(&Key::new(name, labels))
            .and_then(|h| h.quantile(q))
    }

    /// Snapshot one histogram series (tests, derived metrics).
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Option<Histogram> {
        crate::util::lock_unpoisoned(&self.histograms).get(&Key::new(name, labels)).cloned()
    }

    /// Render the Prometheus text exposition: `# TYPE` headers, one line
    /// per gauge series, and `_bucket`/`_sum`/`_count` (cumulative buckets)
    /// per histogram series.
    pub fn render(&self) -> String {
        let values = crate::util::lock_unpoisoned(&self.values);
        let histograms = crate::util::lock_unpoisoned(&self.histograms);
        let mut out = String::new();
        let mut last_name = "";
        for (k, v) in values.iter() {
            if k.name != last_name {
                out.push_str(&format!("# TYPE tallfat_{} gauge\n", k.name));
                last_name = &k.name;
            }
            out.push_str(&format!("tallfat_{}{} {v}\n", k.name, k.render_labels(None)));
        }
        last_name = "";
        for (k, h) in histograms.iter() {
            if k.name != last_name {
                out.push_str(&format!("# TYPE tallfat_{} histogram\n", k.name));
                last_name = &k.name;
            }
            let mut cum = 0u64;
            for (i, &c) in h.counts.iter().enumerate() {
                cum += c;
                let le = if i == HISTOGRAM_BUCKETS {
                    "+Inf".to_string()
                } else {
                    format!("{}", bucket_edge(i))
                };
                out.push_str(&format!(
                    "tallfat_{}_bucket{} {cum}\n",
                    k.name,
                    k.render_labels(Some(("le", &le)))
                ));
            }
            out.push_str(&format!(
                "tallfat_{}_sum{} {}\n",
                k.name,
                k.render_labels(None),
                h.sum
            ));
            out.push_str(&format!(
                "tallfat_{}_count{} {}\n",
                k.name,
                k.render_labels(None),
                h.count
            ));
        }
        if values.is_empty() && histograms.is_empty() {
            out.push_str("# no metrics recorded yet\n");
        }
        out
    }
}

/// Route one metrics-plane request (pure, so the table is unit-testable
/// without sockets).
fn route(req: &HttpRequest) -> HttpResponse {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/metrics") => {
            HttpResponse::ok("text/plain; version=0.0.4", MetricsRegistry::global().render())
        }
        ("GET", "/healthz") => HttpResponse::text(200, "ok\n"),
        ("GET", _) => HttpResponse::text(404, "unknown route (GET /metrics, GET /healthz)\n"),
        _ => HttpResponse::text(405, "method not allowed (GET only)\n"),
    }
}

/// The metrics plane's [`NetHandler`]: everything answers inline on the
/// event loop — a metrics endpoint must never sit behind a busy pool.
struct MetricsHandler;

impl NetHandler for MetricsHandler {
    fn handle(&self, req: HttpRequest) -> HttpResponse {
        route(&req)
    }

    fn handle_inline(&self, req: &HttpRequest) -> Option<HttpResponse> {
        Some(route(req))
    }
}

/// `serve-metrics [--addr host:port] [--once] [--max-requests N]`, plus
/// the shared connection-runtime flags (`--max-inflight`, `--max-queue`,
/// `--idle-timeout-ms`, `--keep-alive`/`--no-keep-alive`).
///
/// `--once` answers a single request and exits; `--max-requests N`
/// answers N then exits (both used by integration tests; production runs
/// loop forever).
pub fn serve_metrics(args: &Args) -> Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:9924");
    // Everything answers inline, so the pool just needs to exist.
    let mut nopts =
        NetOptions { plane: "metrics", max_inflight: 2, ..NetOptions::default() }.with_args(args)?;
    let max_requests = args.u64_or("max-requests", 0)?;
    if args.flag("once") {
        nopts.max_requests = Some(1);
    } else if max_requests > 0 {
        nopts.max_requests = Some(max_requests);
    }
    let server = NetServer::bind(&addr, nopts)?;
    LOG.info(&format!("metrics on http://{}/metrics", server.local_addr()?));
    server.run(Arc::new(MetricsHandler))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn registry_set_add_get() {
        let reg = MetricsRegistry::default();
        reg.set("rows_per_sec", 123.5);
        reg.add("rows_total", 100.0);
        reg.add("rows_total", 50.0);
        assert_eq!(reg.get("rows_per_sec"), Some(123.5));
        assert_eq!(reg.get("rows_total"), Some(150.0));
        let text = reg.render();
        assert!(text.contains("tallfat_rows_per_sec 123.5"));
        assert!(text.contains("tallfat_rows_total 150"));
        assert!(text.contains("# TYPE tallfat_rows_per_sec gauge"));
    }

    #[test]
    fn empty_registry_renders_comment() {
        let reg = MetricsRegistry::default();
        assert!(reg.render().starts_with('#'));
    }

    #[test]
    fn labeled_series_are_distinct_and_order_insensitive() {
        let reg = MetricsRegistry::default();
        reg.add_labeled("jobs", &[("kind", "update")], 1.0);
        reg.add_labeled("jobs", &[("kind", "stream")], 2.0);
        // Same series regardless of label order at the call site.
        reg.add_labeled("dual", &[("a", "1"), ("b", "2")], 1.0);
        reg.add_labeled("dual", &[("b", "2"), ("a", "1")], 1.0);
        assert_eq!(reg.get_labeled("jobs", &[("kind", "update")]), Some(1.0));
        assert_eq!(reg.get_labeled("jobs", &[("kind", "stream")]), Some(2.0));
        assert_eq!(reg.get_labeled("dual", &[("a", "1"), ("b", "2")]), Some(2.0));
        assert_eq!(reg.get("jobs"), None, "labeled series must not shadow the bare name");
        let text = reg.render();
        assert!(text.contains("tallfat_jobs{kind=\"update\"} 1"));
        assert!(text.contains("tallfat_jobs{kind=\"stream\"} 2"));
        assert!(text.contains("tallfat_dual{a=\"1\",b=\"2\"} 2"));
        // One TYPE header per metric name, not per series.
        assert_eq!(text.matches("# TYPE tallfat_jobs gauge").count(), 1);
    }

    #[test]
    fn label_values_escape_backslash_quote_newline() {
        let reg = MetricsRegistry::default();
        reg.set_labeled("paths", &[("dir", "C:\\tmp\"x\"\nend")], 1.0);
        let text = reg.render();
        assert!(
            text.contains(r#"tallfat_paths{dir="C:\\tmp\"x\"\nend"} 1"#),
            "bad escaping: {text}"
        );
    }

    #[test]
    fn histogram_bucket_edges_are_log_spaced_and_inclusive() {
        let edges = bucket_upper_edges();
        assert_eq!(edges.len(), HISTOGRAM_BUCKETS);
        assert_eq!(edges[0], 1e-3);
        for w in edges.windows(2) {
            assert!((w[1] / w[0] - 2.0).abs() < 1e-12, "edges must double");
        }
        // A value exactly on an edge lands in that bucket (le semantics).
        let mut h = Histogram::default();
        h.observe(bucket_edge(5));
        assert_eq!(h.counts()[5], 1);
        // Just above the edge spills into the next bucket.
        let mut h = Histogram::default();
        h.observe(bucket_edge(5) * 1.0001);
        assert_eq!(h.counts()[6], 1);
        // Past the last finite edge: overflow bucket.
        let mut h = Histogram::default();
        h.observe(bucket_edge(HISTOGRAM_BUCKETS - 1) * 4.0);
        assert_eq!(h.counts()[HISTOGRAM_BUCKETS], 1);
    }

    #[test]
    fn histogram_quantiles_on_known_distribution() {
        let reg = MetricsRegistry::default();
        // 100 observations at 1..=100 ms: p50 ≈ 50, p99 ≈ 99.
        for v in 1..=100 {
            reg.observe("lat_ms", v as f64);
        }
        let p50 = reg.quantile("lat_ms", 0.5).unwrap();
        let p99 = reg.quantile("lat_ms", 0.99).unwrap();
        // The estimate is bucketed: correct to within the winning bucket.
        let width_at = |v: f64| {
            let i = bucket_index(v);
            bucket_edge(i) - if i == 0 { 0.0 } else { bucket_edge(i - 1) }
        };
        assert!((p50 - 50.0).abs() <= width_at(50.0), "p50={p50}");
        assert!((p99 - 99.0).abs() <= width_at(99.0), "p99={p99}");
        assert!(p50 <= p99);
        // Extremes are defined too.
        assert!(reg.quantile("lat_ms", 0.0).unwrap() <= reg.quantile("lat_ms", 1.0).unwrap());
    }

    #[test]
    fn empty_and_missing_histograms_have_no_quantile() {
        let reg = MetricsRegistry::default();
        assert_eq!(reg.quantile("nothing", 0.5), None);
        let h = Histogram::default();
        assert_eq!(h.quantile(0.5), None);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn histogram_renders_cumulative_buckets_sum_count() {
        let reg = MetricsRegistry::default();
        reg.observe_labeled("req_ms", &[("op", "project")], 0.5);
        reg.observe_labeled("req_ms", &[("op", "project")], 3.0);
        let text = reg.render();
        assert!(text.contains("# TYPE tallfat_req_ms histogram"));
        // 0.5 <= 0.512 (bucket 9); cumulative count at le=0.512 is 1.
        assert!(text.contains("tallfat_req_ms_bucket{op=\"project\",le=\"0.512\"} 1"), "{text}");
        assert!(text.contains("tallfat_req_ms_bucket{op=\"project\",le=\"+Inf\"} 2"), "{text}");
        assert!(text.contains("tallfat_req_ms_sum{op=\"project\"} 3.5"));
        assert!(text.contains("tallfat_req_ms_count{op=\"project\"} 2"));
    }

    #[test]
    fn concurrent_observes_from_eight_threads() {
        let reg = std::sync::Arc::new(MetricsRegistry::default());
        std::thread::scope(|scope| {
            for t in 0..8 {
                let reg = reg.clone();
                scope.spawn(move || {
                    for i in 0..1000 {
                        reg.observe("contended_ms", ((t * 1000 + i) % 97) as f64 + 1.0);
                    }
                });
            }
        });
        let h = reg.histogram("contended_ms", &[]).unwrap();
        assert_eq!(h.count(), 8000, "every observe must land exactly once");
        assert!(h.quantile(0.5).is_some());
    }

    fn one_request(addr: &std::net::SocketAddr, req: &str) -> String {
        let mut resp = String::new();
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        s.read_to_string(&mut resp).unwrap();
        resp
    }

    #[test]
    fn routes_metrics_healthz_404_405() {
        let nopts = NetOptions {
            plane: "metrics",
            max_inflight: 2,
            max_requests: Some(4),
            ..NetOptions::default()
        };
        let server = NetServer::bind("127.0.0.1:0", nopts).unwrap();
        let addr = server.local_addr().unwrap();
        MetricsRegistry::global().set("test_routing_gauge", 3.0);
        let join = std::thread::spawn(move || server.run(Arc::new(MetricsHandler)));
        let metrics =
            one_request(&addr, "GET /metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
        assert!(metrics.contains("200 OK"), "{metrics}");
        assert!(metrics.contains("tallfat_test_routing_gauge 3"), "{metrics}");
        let health =
            one_request(&addr, "GET /healthz HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
        assert!(health.contains("200 OK") && health.contains("ok"), "{health}");
        let missing =
            one_request(&addr, "GET /nope HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
        assert!(missing.contains("404 Not Found"), "{missing}");
        let post =
            one_request(&addr, "POST /metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
        assert!(post.contains("405 Method Not Allowed"), "{post}");
        join.join().unwrap().unwrap();
    }

    #[test]
    fn serves_one_http_request() {
        // Bind on an ephemeral port by racing: pick a port via a probe bind.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        MetricsRegistry::global().set("test_gauge", 7.0);
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            let args = Args::parse(
                ["serve-metrics", "--addr", &addr2, "--once"].iter().map(|s| s.to_string()),
            )
            .unwrap();
            serve_metrics(&args).unwrap();
        });
        // Retry connect until the listener is up.
        let mut resp = String::new();
        for _ in 0..100 {
            if let Ok(mut s) = TcpStream::connect(&addr) {
                s.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
                s.read_to_string(&mut resp).unwrap();
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        server.join().unwrap();
        assert!(resp.contains("200 OK"), "{resp}");
        assert!(resp.contains("tallfat_test_gauge 7"), "{resp}");
    }
}
