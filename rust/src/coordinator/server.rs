//! `serve-metrics`: a dependency-free HTTP endpoint exposing run metrics.
//!
//! The paper's cluster story needs the leader to be observable; this is the
//! minimal honest version — a blocking `TcpListener` loop answering any
//! `GET` with `text/plain` Prometheus-style gauges from a shared
//! [`MetricsRegistry`]. Jobs publish into the registry; scrapers poll.

use crate::error::Result;
use crate::util::{Args, Logger};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Mutex, OnceLock};

static LOG: Logger = Logger::new("metrics-server");

/// Process-global metric registry (name -> value).
#[derive(Default)]
pub struct MetricsRegistry {
    values: Mutex<BTreeMap<String, f64>>,
}

impl MetricsRegistry {
    /// The process-wide registry.
    pub fn global() -> &'static MetricsRegistry {
        static REG: OnceLock<MetricsRegistry> = OnceLock::new();
        REG.get_or_init(MetricsRegistry::default)
    }

    /// Set a gauge.
    pub fn set(&self, name: &str, value: f64) {
        crate::util::lock_unpoisoned(&self.values).insert(name.to_string(), value);
    }

    /// Add to a counter (creates at 0).
    pub fn add(&self, name: &str, delta: f64) {
        *crate::util::lock_unpoisoned(&self.values).entry(name.to_string()).or_insert(0.0) += delta;
    }

    /// Read one metric.
    pub fn get(&self, name: &str) -> Option<f64> {
        crate::util::lock_unpoisoned(&self.values).get(name).copied()
    }

    /// Render the Prometheus text exposition.
    pub fn render(&self) -> String {
        let values = crate::util::lock_unpoisoned(&self.values);
        let mut out = String::new();
        for (k, v) in values.iter() {
            out.push_str(&format!("tallfat_{k} {v}\n"));
        }
        if values.is_empty() {
            out.push_str("# no metrics recorded yet\n");
        }
        out
    }
}

fn handle(mut stream: TcpStream) -> std::io::Result<()> {
    // Read the request line; drain headers until the blank line.
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut hdr = String::new();
    while reader.read_line(&mut hdr)? > 0 {
        if hdr == "\r\n" || hdr == "\n" {
            break;
        }
        hdr.clear();
    }
    let body = MetricsRegistry::global().render();
    let response = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    stream.write_all(response.as_bytes())
}

/// `serve-metrics [--addr host:port] [--once]`.
///
/// `--once` answers a single request and exits (used by the integration
/// test; production runs loop forever).
pub fn serve_metrics(args: &Args) -> Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:9924");
    let listener = TcpListener::bind(&addr)?;
    LOG.info(&format!("metrics on http://{addr}/metrics"));
    let once = args.flag("once");
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                if let Err(e) = handle(s) {
                    LOG.warn(&format!("request failed: {e}"));
                }
            }
            Err(e) => LOG.warn(&format!("accept failed: {e}")),
        }
        if once {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    #[test]
    fn registry_set_add_get() {
        let reg = MetricsRegistry::default();
        reg.set("rows_per_sec", 123.5);
        reg.add("rows_total", 100.0);
        reg.add("rows_total", 50.0);
        assert_eq!(reg.get("rows_per_sec"), Some(123.5));
        assert_eq!(reg.get("rows_total"), Some(150.0));
        let text = reg.render();
        assert!(text.contains("tallfat_rows_per_sec 123.5"));
        assert!(text.contains("tallfat_rows_total 150"));
    }

    #[test]
    fn empty_registry_renders_comment() {
        let reg = MetricsRegistry::default();
        assert!(reg.render().starts_with('#'));
    }

    #[test]
    fn serves_one_http_request() {
        // Bind on an ephemeral port by racing: pick a port via a probe bind.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        MetricsRegistry::global().set("test_gauge", 7.0);
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            let args = Args::parse(
                ["serve-metrics", "--addr", &addr2, "--once"].iter().map(|s| s.to_string()),
            )
            .unwrap();
            serve_metrics(&args).unwrap();
        });
        // Retry connect until the listener is up.
        let mut resp = String::new();
        for _ in 0..100 {
            if let Ok(mut s) = TcpStream::connect(&addr) {
                s.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
                s.read_to_string(&mut resp).unwrap();
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        server.join().unwrap();
        assert!(resp.contains("200 OK"), "{resp}");
        assert!(resp.contains("tallfat_test_gauge 7"), "{resp}");
    }
}
