//! Subcommand implementations (leader-side orchestration).

use crate::backend::{make_backend, BackendRef};
use crate::config::{InputFormat, RunConfig};
use crate::error::{Error, Result};
use crate::io::dataset::{self, Spectrum};
use crate::io::writer::ShardSet;
use crate::io::InputSpec;
use crate::jobs::{AtaBlockJob, AtaRowJob, MultJob, RandomProjRowJob};
use crate::linalg::Matrix;
use crate::mapreduce::{ata_mapreduce, AtaMrMode};
use crate::metrics::Stopwatch;
use crate::rng::VirtualMatrix;
use crate::simulator::{simulate_split_process, ClusterParams};
use crate::splitproc::{self, Blocked};
use crate::svd;
use crate::util::{Args, Logger};

static LOG: Logger = Logger::new("coordinator");

/// Build the run configuration: defaults < `--config` file < CLI flags.
pub fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig::default();
    if let Some(path) = args.opt_str("config") {
        let file = crate::config::parser::ConfigFile::parse_file(path)?;
        cfg.apply_file(&file)?;
    }
    cfg.apply_args(args)?;
    Ok(cfg)
}

fn input_of(cfg: &RunConfig) -> Result<InputSpec> {
    if cfg.input.is_empty() {
        return Err(Error::Config("--input is required".into()));
    }
    Ok(InputSpec { path: cfg.input.clone(), format: cfg.format })
}

/// Print the run's chunk-scheduler counters (published per pass by the
/// executors into the shared registry).
fn print_sched_summary() {
    let reg = crate::coordinator::server::MetricsRegistry::global();
    if let Some(total) = reg.get("pass_chunks_total") {
        let retried = reg.get("pass_chunks_retried").unwrap_or(0.0);
        let speculated = reg.get("pass_chunks_speculated").unwrap_or(0.0);
        println!(
            "scheduler: {} chunks planned, {} executions ({} retried, {} speculated), \
             last-pass skew {:.1} ms",
            total,
            total + retried + speculated,
            retried,
            speculated,
            reg.get("pass_skew_ms").unwrap_or(0.0),
        );
    }
}

fn parse_spectrum(args: &Args, rank: usize) -> Result<Spectrum> {
    let scale = args.f64_or("scale", 10.0)?;
    match args.str_or("spectrum", "geometric").as_str() {
        "geometric" => Ok(Spectrum::Geometric { scale, decay: args.f64_or("decay", 0.7)? }),
        "power" => Ok(Spectrum::Power { scale }),
        "lowrank" => Ok(Spectrum::LowRank { scale, r: rank }),
        other => Err(Error::Config(format!("unknown spectrum `{other}`"))),
    }
}

/// `gen-data`: write a synthetic dataset to `--out`.
pub fn gen_data(args: &Args) -> Result<()> {
    let out = args.require_str("out")?;
    let m = args.usize_or("rows", 10_000)?;
    let n = args.usize_or("cols", 64)?;
    let rank = args.usize_or("rank", n.min(16))?;
    let noise = args.f64_or("noise", 0.01)?;
    let seed = args.u64_or("seed", 0)?;
    let spectrum = parse_spectrum(args, rank)?;
    let spec = InputSpec::auto(out.clone());
    let sw = Stopwatch::start();
    if spec.format.is_sparse() {
        // Sparse outputs (libsvm/scsv/csr): hashed pattern at --density.
        let density = args.f64_or("density", 0.05)?;
        let nnz = dataset::gen_sparse_streamed(&spec, m, n, density, seed)?;
        LOG.info(&format!(
            "streamed {m}x{n} sparse ({nnz} nnz, {:.1}% fill) to {out}",
            100.0 * nnz as f64 / (m as f64 * n as f64).max(1.0)
        ));
    } else if args.flag("clusters") || args.opt_str("clusters").is_some() {
        let clusters = args.usize_or("clusters", 8)?;
        let spread = args.f64_or("spread", 0.5)?;
        let (a, _) = dataset::gen_clustered(m, n, clusters, spread, seed);
        crate::io::write_matrix(&a, &spec)?;
        LOG.info(&format!("wrote {m}x{n} clustered ({clusters} clusters) to {out}"));
    } else if out == "-" || args.flag("streamed") || m * n > 50_000_000 {
        // `--out -` always takes the streaming generator: rows go straight
        // to stdout (and no `.sigma` sidecar file is attempted).
        dataset::gen_streamed(&spec, m, n, rank, spectrum, noise, seed)?;
        LOG.info(&format!("streamed {m}x{n} rank~{rank} to {out}"));
    } else {
        let (a, sigma) = dataset::gen_exact(m, n, rank, spectrum, noise, seed)?;
        crate::io::write_matrix(&a, &spec)?;
        // Exact spectrum alongside, for accuracy experiments.
        let sigma_path = format!("{out}.sigma");
        let text: String =
            sigma.iter().map(|s| format!("{s:.12e}\n")).collect();
        std::fs::write(&sigma_path, text)?;
        LOG.info(&format!("wrote {m}x{n} rank {rank} to {out} (+ {sigma_path})"));
    }
    LOG.info(&format!("gen-data done in {:.2?}", sw.elapsed()));
    Ok(())
}

/// `svd` / `exact-svd`: the paper's pipeline end to end, through the
/// builder API. `--distributed` swaps the execution substrate for a
/// [`crate::cluster::ClusterExecutor`]; the pipeline itself is identical.
pub fn svd(args: &Args, exact: bool) -> Result<()> {
    let mut cfg = load_config(args)?;
    if exact {
        cfg.exact_gram = true;
    }
    let input = input_of(&cfg)?;
    let sw = Stopwatch::start();
    let _trace = crate::obs::trace::TraceGuard::start(
        args.opt_str("trace"),
        if exact { "exact-svd" } else { "svd" },
    )?;
    let mut builder = svd::Svd::from_config(&cfg)?;
    if let Some(model_dir) = args.opt_str("save-model") {
        builder = builder.save_model(model_dir);
    }
    let result = if args.flag("distributed") {
        let listen = args.str_or("listen", "127.0.0.1:7070");
        let n = args.usize_or("remote-workers", cfg.workers)?;
        let mut cluster = crate::cluster::ClusterExecutor::accept(&listen, n)?;
        let res = builder.executor(&mut cluster).run();
        // Surface the run error first: a shutdown-send failure to a dead
        // worker must not mask why the run itself failed.
        let shutdown = cluster.shutdown();
        let out = res?;
        shutdown?;
        out
    } else {
        builder.run()?
    };
    println!("{}", result.report.render());
    print_sched_summary();
    println!(
        "m={} n={} k={}  sigma = [{}]",
        result.m,
        result.n,
        result.k,
        result
            .sigma
            .iter()
            .take(8)
            .map(|s| format!("{s:.4}"))
            .collect::<Vec<_>>()
            .join(", ")
    );
    if args.flag("validate") {
        let err = svd::validate::reconstruction_error_streaming(&input, &result)?;
        println!("relative reconstruction error ||A - U S V^T||_F / ||A||_F = {err:.6}");
    }
    if let Some(prefix) = args.opt_str("out-prefix") {
        write_outputs(prefix, &result)?;
    }
    LOG.info(&format!("svd done in {:.2?}", sw.elapsed()));
    Ok(())
}

fn write_outputs(prefix: &str, result: &svd::SvdResult) -> Result<()> {
    let sigma_path = format!("{prefix}.sigma.csv");
    let text: String = result.sigma.iter().map(|s| format!("{s:.12e}\n")).collect();
    std::fs::write(&sigma_path, text)?;
    if let Some(v) = &result.v {
        crate::io::csv::write_matrix_csv(v, &format!("{prefix}.V.csv"))?;
    }
    LOG.info(&format!(
        "wrote {prefix}.sigma.csv{}; U stays sharded in {}",
        if result.v.is_some() { format!(" and {prefix}.V.csv") } else { String::new() },
        result.u_shards.shard_path(0),
    ));
    Ok(())
}

/// `update <model-dir> --rows PATH`: append a row batch to a saved model
/// as the next generation — streaming passes over the batch only, a
/// `(k+r)`-sized merge on the leader, then an atomic `CURRENT` repoint
/// ([`crate::update`]). `--distributed` runs the passes on remote workers
/// exactly like `svd --distributed`.
pub fn update(args: &Args) -> Result<()> {
    let model_dir = args
        .opt_str("model-dir")
        .map(str::to_string)
        .or_else(|| args.positional.first().cloned())
        .ok_or_else(|| {
            Error::Config("update: model directory required (positional or --model-dir)".into())
        })?;
    let rows = args.require_str("rows")?;
    let cfg = load_config(args)?;
    let input = InputSpec::auto(rows.to_string());
    let sw = Stopwatch::start();
    let _trace = crate::obs::trace::TraceGuard::start(args.opt_str("trace"), "update")?;
    let mut builder = crate::update::Update::of(&model_dir)?
        .rows(&input)
        .oversample(cfg.oversample)
        .workers(cfg.workers)
        .block(cfg.block)
        .seed(cfg.seed)
        .sigma_cutoff_rel(cfg.sigma_cutoff_rel)
        .chunk_rows(cfg.chunk_rows)
        .chunks_per_worker(cfg.chunks_per_worker)
        .chunk_retries(cfg.chunk_retries)
        .keep_generations(args.usize_or("keep-generations", 2)?)
        .backend(make_backend(&cfg)?);
    // Only an *explicit* --work-dir overrides the builder's unique
    // per-invocation scratch directory — the shared config default would
    // let two concurrent updates corrupt each other's shards.
    if let Some(d) = args.opt_str("work-dir") {
        builder = builder.work_dir(d);
    }
    if let Some(k) = args.opt_str("rank") {
        let k = k
            .parse::<usize>()
            .map_err(|_| Error::Config(format!("update: bad --rank `{k}`")))?;
        builder = builder.rank(k);
    }
    let result = if args.flag("distributed") {
        let listen = args.str_or("listen", "127.0.0.1:7070");
        let n = args.usize_or("remote-workers", cfg.workers)?;
        let mut cluster = crate::cluster::ClusterExecutor::accept(&listen, n)?;
        let res = builder.executor(&mut cluster).run();
        // Surface the run error first: a shutdown-send failure to a dead
        // worker must not mask why the run itself failed.
        let shutdown = cluster.shutdown();
        let out = res?;
        shutdown?;
        out
    } else {
        builder.run()?
    };
    println!("{}", result.report.render());
    print_sched_summary();
    println!(
        "generation {}: m={} n={} k={} (+{} rows)  sigma = [{}]",
        result.generation,
        result.m,
        result.n,
        result.k,
        result.rows_added,
        result
            .sigma
            .iter()
            .take(8)
            .map(|s| format!("{s:.4}"))
            .collect::<Vec<_>>()
            .join(", ")
    );
    LOG.info(&format!("update done in {:.2?} -> {}", sw.elapsed(), result.dir.display()));
    Ok(())
}

/// `stream`: one-pass streaming SVD over a forward-only source
/// ([`crate::stream`]). The input may be `-` (stdin), a pipe/FIFO, or a
/// regular file; rows are read exactly once and the sketch widens
/// adaptively until `--tol` is met or `--max-rank` is hit.
pub fn stream(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let input = args
        .positional
        .first()
        .cloned()
        .or_else(|| (!cfg.input.is_empty()).then(|| cfg.input.clone()))
        .ok_or_else(|| {
            Error::Config(
                "stream: input required (positional path, `-` for stdin, or --input)".into(),
            )
        })?;
    let sw = Stopwatch::start();
    let _trace = crate::obs::trace::TraceGuard::start(args.opt_str("trace"), "stream")?;
    let mut builder = crate::stream::StreamSvd::open(&input)
        .tol(cfg.tol)
        .max_rank(cfg.max_rank)
        .batch_rows(cfg.batch_rows)
        .start_width(args.usize_or("start-width", crate::stream::DEFAULT_START_WIDTH)?)
        .oversample(cfg.oversample)
        .center(cfg.center)
        .seed(cfg.seed)
        .cols(cfg.cols)
        .work_dir(&cfg.work_dir)
        .sigma_cutoff_rel(cfg.sigma_cutoff_rel)
        .backend(make_backend(&cfg)?)
        .checkpoint(args.flag("checkpoint") || args.flag("resume"))
        .checkpoint_interval(std::time::Duration::from_secs(args.usize_or(
            "checkpoint-every",
            crate::stream::DEFAULT_CHECKPOINT_INTERVAL.as_secs() as usize,
        )? as u64))
        .resume(args.flag("resume"));
    // The extension guess only works on real paths; `--input-format` is the
    // explicit override (and the only way to frame stdin as anything but csv).
    if let Some(f) = args.opt_str("input-format") {
        builder = builder.format(InputFormat::parse(f)?);
    }
    let rank = args.usize_or("rank", 0)?;
    if rank > 0 {
        builder = builder.rank(rank);
    }
    if let Some(dir) = args.opt_str("save-model") {
        builder = builder.save_model(dir);
    }
    let result = builder.run()?;
    println!("{}", result.report.render());
    let reg = crate::coordinator::server::MetricsRegistry::global();
    println!(
        "m={} n={} k={}  width={} widenings={} residual~{:.2e}  sigma = [{}]",
        result.m,
        result.n,
        result.k,
        reg.get("stream_width").unwrap_or(0.0) as usize,
        reg.get("stream_widenings").unwrap_or(0.0) as usize,
        reg.get("stream_residual").unwrap_or(f64::NAN),
        result
            .sigma
            .iter()
            .take(8)
            .map(|s| format!("{s:.4}"))
            .collect::<Vec<_>>()
            .join(", ")
    );
    LOG.info(&format!("stream done in {:.2?}", sw.elapsed()));
    Ok(())
}

/// `ata`: standalone streaming Gram (paper §3.1).
pub fn ata(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let input = input_of(&cfg)?;
    let (m, n) = input.dims()?;
    let sw = Stopwatch::start();
    let gram = run_ata(&cfg, &input, n, args.flag("row-mode"))?;
    let elapsed = sw.elapsed();
    println!(
        "A^T A of {m}x{n} in {:.2?} ({:.0} rows/s), trace = {:.4}",
        elapsed,
        m as f64 / elapsed.as_secs_f64(),
        (0..n).map(|i| gram.get(i, i)).sum::<f64>()
    );
    if let Some(out) = args.opt_str("out") {
        crate::io::write_matrix(&gram, &InputSpec::auto(out))?;
    }
    Ok(())
}

/// Shared ATA driver (also used by benches): block mode through the
/// configured backend, or the paper-literal row mode.
pub fn run_ata(cfg: &RunConfig, input: &InputSpec, n: usize, row_mode: bool) -> Result<Matrix> {
    if row_mode {
        let results = splitproc::run(input, cfg.workers, |_| Ok(AtaRowJob::new(n)))?;
        splitproc::reduce_partials(results.into_iter().map(|r| r.job.into_partial()).collect())
    } else {
        let backend: BackendRef = make_backend(cfg)?;
        let results = splitproc::run(input, cfg.workers, |_| {
            Ok(Blocked::new(AtaBlockJob::new(backend.clone(), n), cfg.block, n))
        })?;
        splitproc::reduce_partials(
            results.into_iter().map(|r| r.job.into_inner().into_partial()).collect(),
        )
    }
}

/// `project`: standalone `Y = A Ω` with the virtual Ω (paper §3.3/§2.1).
pub fn project(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let input = input_of(&cfg)?;
    let (m, n) = input.dims()?;
    let k = cfg.sketch_width();
    let omega = VirtualMatrix::projection(cfg.seed, n, k);
    let prefix = args.str_or("out-prefix", &format!("{}/Y", cfg.work_dir));
    let dir = std::path::Path::new(&prefix)
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| ".".into());
    std::fs::create_dir_all(&dir)?;
    let stem = std::path::Path::new(&prefix)
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "Y".into());
    let shards = ShardSet::new(&dir, &stem, InputFormat::Csv)?;
    let sw = Stopwatch::start();
    let results = splitproc::run(&input, cfg.workers, |chunk| {
        RandomProjRowJob::new(omega.clone(), &shards, chunk.index)
    })?;
    let rows: u64 = results.iter().map(|r| r.rows).sum();
    println!(
        "projected {m}x{n} -> {rows}x{k} in {:.2?} ({} shards at {})",
        sw.elapsed(),
        results.len(),
        shards.shard_path(0)
    );
    Ok(())
}

/// `mult`: streaming `A·B` with a materialized B (paper §3.2).
pub fn mult(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let input = input_of(&cfg)?;
    let b_path = args.require_str("b")?;
    let b_spec = InputSpec::auto(b_path);
    let backend = make_backend(&cfg)?;
    let prefix = args.str_or("out-prefix", &format!("{}/C", cfg.work_dir));
    let dir = std::path::Path::new(&prefix)
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| ".".into());
    std::fs::create_dir_all(&dir)?;
    let stem = std::path::Path::new(&prefix)
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "C".into());
    let shards = ShardSet::new(&dir, &stem, InputFormat::Csv)?;
    let (_, n) = input.dims()?;
    let sw = Stopwatch::start();
    let results = splitproc::run(&input, cfg.workers, |chunk| {
        let job = MultJob::from_file(backend.clone(), &b_spec, &shards, chunk.index)?;
        Ok(Blocked::new(job, cfg.block, n))
    })?;
    let rows: u64 = results.iter().map(|r| r.rows).sum();
    println!("multiplied {rows} rows in {:.2?} -> {}", sw.elapsed(), shards.shard_path(0));
    Ok(())
}

/// `mr-ata`: the Map-Reduce baseline with shuffle accounting (E2).
pub fn mr_ata(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let input = input_of(&cfg)?;
    let (m, n) = input.dims()?;
    let mappers = args.usize_or("mappers", cfg.workers)?;
    let reducers = args.usize_or("reducers", cfg.workers)?;
    let mode = if args.flag("upper") { AtaMrMode::Upper } else { AtaMrMode::Full };
    let work = std::path::Path::new(&cfg.work_dir).join("mr_ata");
    let sw = Stopwatch::start();
    let (gram, stats) = ata_mapreduce(&input, work, mappers, reducers, mode)?;
    let elapsed = sw.elapsed();
    println!(
        "MR A^T A of {m}x{n}: {:.2?}, {} pairs, shuffle {} (trace {:.4})",
        elapsed,
        stats.pairs_emitted,
        crate::util::humanize::fmt_bytes(stats.shuffle_bytes),
        (0..n).map(|i| gram.get(i, i)).sum::<f64>()
    );
    Ok(())
}

/// `simulate`: scalability sweep on the cluster cost model (E1).
pub fn simulate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let input = input_of(&cfg)?;
    let (m, n) = input.dims()?;
    let params = cluster_params_from(args)?;
    let partial_bytes = args.u64_or("partial-bytes", (n * n * 8) as u64)?;
    let list = args.str_or("workers-list", "1,2,4,8,16");
    let workers: Vec<usize> = list
        .split(',')
        .map(|t| t.trim().parse::<usize>().map_err(|e| Error::parse(format!("{e}"))))
        .collect::<Result<_>>()?;
    let io_desc = if params.local_copies {
        "local copies (no shared server)".to_string()
    } else {
        format!("{}/s shared file server", crate::util::humanize::fmt_bytes(params.fileserver_bw as u64))
    };
    println!(
        "simulated cluster: {m} rows x {n} cols, cpu {:.0} rows/s, {io_desc}",
        params.cpu_rows_per_sec
    );
    println!("{:>8} {:>12} {:>12} {:>12} {:>9}", "workers", "stream(s)", "reduce(s)", "total(s)", "speedup");
    let base = simulate_split_process(&params, &input, 1, partial_bytes)?.makespan;
    for &w in &workers {
        let r = simulate_split_process(&params, &input, w, partial_bytes)?;
        println!(
            "{:>8} {:>12.4} {:>12.4} {:>12.4} {:>8.2}x",
            r.workers, r.stream_makespan, r.reduce_time, r.makespan, base / r.makespan
        );
    }
    Ok(())
}

/// `worker`: join a distributed run and serve phases until shutdown.
pub fn worker(args: &Args) -> Result<()> {
    let leader = args.require_str("leader")?;
    let cfg = load_config(args)?;
    let backend = make_backend(&cfg)?;
    crate::cluster::run_worker(&leader, backend)
}

/// `trace-summary <trace.json>`: digest a `--trace` file into per-phase
/// critical paths, the slowest chunks, and worker utilization.
pub fn trace_summary(args: &Args) -> Result<()> {
    let path = args
        .opt_str("file")
        .map(str::to_string)
        .or_else(|| args.positional.first().cloned())
        .ok_or_else(|| {
            Error::Config("trace-summary: trace file required (positional or --file)".into())
        })?;
    print!("{}", crate::obs::summary::render_summary(&path)?);
    Ok(())
}

/// Parse [`ClusterParams`] overrides from the CLI.
pub fn cluster_params_from(args: &Args) -> Result<ClusterParams> {
    let d = ClusterParams::default();
    Ok(ClusterParams {
        nodes: args.usize_or("nodes", d.nodes)?,
        cpu_rows_per_sec: args.f64_or("rows-per-sec", d.cpu_rows_per_sec)?,
        fileserver_bw: args.f64_or("fileserver-bw", d.fileserver_bw)?,
        disk_bw: args.f64_or("disk-bw", d.disk_bw)?,
        local_copies: args.flag("local-copies"),
        reduce_latency: args.f64_or("reduce-latency", d.reduce_latency)?,
        reduce_bw: args.f64_or("reduce-bw", d.reduce_bw)?,
        jitter: args.f64_or("jitter", d.jitter)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("tallfat_test_cmds");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    fn argv(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn gen_data_then_ata_roundtrip() {
        let path = tmp("cmd_a.csv");
        gen_data(&argv(&[
            "gen-data", "--out", &path, "--rows", "60", "--cols", "5", "--rank", "3",
        ]))
        .unwrap();
        let out = tmp("cmd_gram.csv");
        ata(&argv(&["ata", "--input", &path, "--workers", "2", "--out", &out])).unwrap();
        let g = crate::io::read_matrix(&InputSpec::auto(out)).unwrap();
        assert_eq!(g.shape(), (5, 5));
        // Gram is symmetric PSD: diagonal positive.
        for i in 0..5 {
            assert!(g.get(i, i) > 0.0);
        }
    }

    #[test]
    fn svd_command_runs_end_to_end() {
        let path = tmp("cmd_svd.csv");
        gen_data(&argv(&[
            "gen-data", "--out", &path, "--rows", "120", "--cols", "24", "--rank", "4",
            "--noise", "0",
        ]))
        .unwrap();
        let work = tmp("cmd_svd_work");
        svd(
            &argv(&[
                "svd", "--input", &path, "--k", "4", "--workers", "2", "--work-dir", &work,
                "--validate",
            ]),
            false,
        )
        .unwrap();
    }

    #[test]
    fn exact_svd_command_runs() {
        let path = tmp("cmd_exact.csv");
        gen_data(&argv(&[
            "gen-data", "--out", &path, "--rows", "80", "--cols", "8", "--rank", "3", "--noise", "0",
        ]))
        .unwrap();
        let work = tmp("cmd_exact_work");
        svd(
            &argv(&["exact-svd", "--input", &path, "--k", "3", "--work-dir", &work]),
            true,
        )
        .unwrap();
    }

    #[test]
    fn svd_command_runs_on_sparse_input_end_to_end() {
        // gen-data writes libsvm when the extension says so; the svd
        // command picks the sparse path up (here forced via
        // --input-format, the flag the extension guess can be overridden
        // with) and --validate streams the sparse input once more.
        let path = tmp("cmd_sparse.libsvm");
        gen_data(&argv(&[
            "gen-data", "--out", &path, "--rows", "200", "--cols", "24", "--density", "0.15",
        ]))
        .unwrap();
        let work = tmp("cmd_sparse_work");
        svd(
            &argv(&[
                "svd", "--input", &path, "--input-format", "libsvm", "--k", "4",
                "--workers", "2", "--work-dir", &work, "--validate",
            ]),
            false,
        )
        .unwrap();
    }

    #[test]
    fn stream_command_runs_end_to_end() {
        let path = tmp("cmd_stream.csv");
        gen_data(&argv(&[
            "gen-data", "--out", &path, "--rows", "150", "--cols", "20", "--rank", "5",
            "--noise", "0",
        ]))
        .unwrap();
        let work = tmp("cmd_stream_work");
        stream(&argv(&[
            "stream", &path, "--tol", "1e-4", "--batch-rows", "40", "--start-width", "6",
            "--work-dir", &work,
        ]))
        .unwrap();
    }

    #[test]
    fn simulate_command_runs() {
        let path = tmp("cmd_sim.csv");
        gen_data(&argv(&["gen-data", "--out", &path, "--rows", "100", "--cols", "4"])).unwrap();
        simulate(&argv(&[
            "simulate", "--input", &path, "--workers-list", "1,2,4", "--rows-per-sec", "10000",
        ]))
        .unwrap();
    }

    #[test]
    fn missing_input_is_config_error() {
        assert!(ata(&argv(&["ata"])).is_err());
    }
}
