//! The leader process: CLI subcommands, run configuration, phase
//! orchestration, and result assembly.
//!
//! This is the deployment entrypoint of the system — what the paper's
//! operator would invoke on the leader node. Subcommands:
//!
//! | command | what it runs |
//! |---|---|
//! | `gen-data` | synthetic tall-and-fat dataset generators ([`crate::io::dataset`]) |
//! | `svd` | the randomized rank-k SVD pipeline ([`crate::svd`]) |
//! | `exact-svd` | the small-n exact-Gram route (paper §2.0.1) |
//! | `stream` | one-pass streaming SVD with adaptive rank over non-seekable sources ([`crate::stream`]) |
//! | `ata` | standalone streaming `A^T A` (paper §3.1) |
//! | `project` | standalone random projection `Y = A Ω` (paper §3.3) |
//! | `mult` | streaming `A·B` with B from file (paper §3.2) |
//! | `mr-ata` | the Map-Reduce baseline for the same Gram (paper Fig. 2) |
//! | `simulate` | cluster cost simulation / scalability sweep ([`crate::simulator`]) |
//! | `serve` | query a saved factor model over HTTP ([`crate::serve`]) |
//! | `update` | append a row batch to a saved model as a new generation ([`crate::update`]) |
//! | `daemon` | long-running model-fleet daemon: many named models, one front door ([`crate::daemon`]) |
//! | `daemon-client` | control a running daemon: register/list/status/submit-job/drain/halt |
//! | `serve-metrics` | tiny HTTP endpoint exposing the last run's metrics |
//! | `trace-summary` | summarize a `--trace` Chrome trace file ([`crate::obs`]) |
//!
//! Configuration precedence: built-in defaults < `--config file.toml` <
//! CLI flags ([`crate::config`]).

pub mod commands;
pub mod server;

use crate::error::{Error, Result};
use crate::util::Args;

/// Top-level usage text.
pub const USAGE: &str = "\
tallfat — randomized rank-k SVD for tall-and-fat matrices (Bayramli 2013)

USAGE: tallfat <command> [options]

COMMANDS
  gen-data      generate a synthetic dataset
                  --out PATH --rows M --cols N [--rank R] [--spectrum geometric|power|lowrank]
                  [--decay D] [--noise S] [--seed S] [--streamed] [--clusters C --spread S]
                  [--density D]   (sparse outputs: a .libsvm/.scsv/.csr --out
                   streams a ~D-fill sparse matrix instead, default 0.05;
                   --out - streams csv rows to stdout, e.g. piped into
                   `tallfat stream -`)
  svd           randomized rank-k SVD of a tall-and-fat file
                  --input PATH --k K [--oversample P] [--power-iters Q] [--workers W]
                  [--block B] [--seed S] [--backend native|xla|auto] [--work-dir D]
                  [--config FILE] [--no-v] [--validate] [--out-prefix P] [--center]
                  [--save-model DIR] [--shard-format csv|bin] [--sigma-cutoff REL]
                  [--chunks-per-worker C] [--chunk-rows R] [--chunk-retries N]
                  [--input-format csv|bin|libsvm|scsv|csr] [--cols N]
                  [--reduce tree|star] [--band-rows R] [--no-adaptive-chunks]
                  (--center = PCA mode: subtract column means, one extra pass;
                   --cols pins the column dictionary of a sparse input — use
                   the serving width you will update against, so later
                   batches with unseen high indices still fit the model;
                   --save-model persists a servable model directory;
                   --shard-format picks the Y/U intermediate shard format;
                   --sigma-cutoff zeroes sketch values below REL * sigma_max;
                   --chunks-per-worker plans C scheduler chunks per worker
                   [default 4; 1 = old static schedule], --chunk-rows caps a
                   chunk at R rows instead, --chunk-retries bounds per-chunk
                   retries before a pass fails [default 2];
                   --input-format overrides the extension guess — sparse
                   inputs stream as CSR blocks through O(nnz) kernels,
                   locally and with --distributed;
                   --reduce picks the partial-reduction topology [default
                   tree: pairwise merges held on the workers, leader state
                   O(k'^2 log w); star = the old sequential fold],
                   --band-rows sets the W/V reduce band height [default
                   auto], --no-adaptive-chunks disables re-planning chunk
                   granularity from measured chunk times)
  exact-svd     exact-Gram SVD for small n (paper §2.0.1)
                  (same options; projection flags ignored)
  stream        one-pass streaming SVD of a forward-only source
                  <path | -> [--tol 1e-3] [--max-rank 512] [--batch-rows 1024]
                  [--start-width 16] [--rank K] [--oversample P] [--center]
                  [--seed S] [--cols N] [--work-dir D] [--backend ...]
                  [--input-format csv|bin|libsvm|scsv|csr] [--save-model DIR]
                  [--checkpoint] [--checkpoint-every SECS] [--resume]
                  [--config FILE]
                (reads rows exactly once — stdin (`-`), pipes, FIFOs and
                 sockets all work; the sketch starts at --start-width and
                 widens whenever the a posteriori residual estimate exceeds
                 --tol, up to --max-rank; --rank pins the output rank and
                 disables widening; --checkpoint persists the sketch at
                 batch boundaries [at most every --checkpoint-every seconds,
                 default 5; 0 = every batch] so --resume continues a
                 replayed stream from the last checkpointed boundary;
                 --save-model writes the same servable model directory the
                 svd command does)
  ata           streaming A^T A                --input PATH [--workers W] [--block B]
                  [--row-mode] [--backend ...] [--out PATH]
  project       random projection Y = A Ω      --input PATH --k K [--seed S] [--workers W]
                  [--virtual] [--out-prefix P]
  mult          streaming A·B                  --input PATH --b PATH [--workers W] [--out-prefix P]
  mr-ata        Map-Reduce A^T A baseline      --input PATH [--mappers M] [--reducers R] [--upper]
  simulate      cluster scalability simulation --input PATH [--workers-list 1,2,4,8,16]
                  [--rows-per-sec R] [--fileserver-bw B] [--disk-bw B] [--local-copies]
                  [--reduce-latency S] [--jitter J] [--partial-bytes N]
  worker        join a distributed run         --leader HOST:PORT [--backend ...]
                (the `svd` command becomes a leader with --distributed:
                 --listen HOST:PORT --remote-workers N; chunks are scheduled
                 dynamically — a worker may join mid-run and pick up queued
                 chunks, and a dead worker's chunks are re-queued to the rest)
  serve         serve a saved model over HTTP  <model-dir> [--addr 127.0.0.1:9925]
                  [--backend native|xla|auto] [--cache-shards 4] [--batch-window-ms 2]
                  [--max-batch 64] [--reload-poll-ms 5000] [--max-requests N] [--once]
                (answers line-delimited JSON on POST /query: project, similar,
                 reconstruct, info, reload; GET /model, /metrics, /healthz;
                 --reload-poll-ms hot-swaps to new generations automatically)
  update        append rows to a saved model   <model-dir> --rows PATH [--oversample P]
                  [--workers W] [--block B] [--seed S] [--work-dir D] [--backend ...]
                  [--keep-generations 2] [--rank K] [--chunks-per-worker C]
                  [--chunk-rows R] [--chunk-retries N]
                (streams only the new rows, merges with (k+r)-sized leader math,
                 writes the next immutable generation, repoints CURRENT, and
                 garbage-collects old generations; with --distributed the passes
                 run on remote workers: --listen HOST:PORT --remote-workers N)
  daemon        model-fleet daemon             <state-dir> [--addr 127.0.0.1:9935]
                  [--backend native|xla|auto] [--cache-shards 4] [--batch-window-ms 2]
                  [--max-batch 64] [--health-poll-ms 2000]
                (one long-running process serving many named models: queries
                 carry \"model\":\"name\" on POST /query; control ops register/
                 list/status/submit-job/job-status/drain/halt ride the same
                 transport; update jobs run supervised in the background —
                 queued per model, health-probed, retried, hot-swapped into
                 serving on publish; fleet and job queue persist under
                 <state-dir> across restarts)
  daemon-client drive a running daemon         <action> [--addr 127.0.0.1:9935]
                  register --name N --root DIR | list | status
                  | submit-job --model N --rows PATH [--rank K] [--seed S]
                      [--stream | --kind update|stream] [--tol 1e-3]
                      [--max-rank 512] [--batch-rows 1024]
                      [--max-attempts 2] [--delay-ms 0] [--wait [--wait-secs 600]]
                  | job-status --id N | drain | halt
  serve-metrics HTTP metrics endpoint          [--addr 127.0.0.1:9924] [--once]
  trace-summary summarize a trace file         <trace.json>
                (per-phase critical path, top slowest chunks, and a worker
                 utilization table, from a file written by --trace)

GLOBAL
  --log error|warn|info|debug|trace   (or TALLFAT_LOG; TALLFAT_LOG_FORMAT=json
                                       switches log lines to structured JSON)
  --trace FILE  (svd, exact-svd, update, stream, serve, daemon: write a
                 Chrome trace-event timeline — open in Perfetto, or feed to
                 `tallfat trace-summary`; distributed svd merges every
                 worker's chunks into the leader's file)
";

/// Dispatch a parsed command line. Returns the process exit code.
pub fn run_cli(args: &Args) -> Result<()> {
    if let Some(level) = args.opt_str("log") {
        crate::util::logger::set_level(parse_level(level));
    }
    match args.command.as_deref() {
        Some("gen-data") => commands::gen_data(args),
        Some("svd") => commands::svd(args, false),
        Some("exact-svd") => commands::svd(args, true),
        Some("stream") => commands::stream(args),
        Some("ata") => commands::ata(args),
        Some("project") => commands::project(args),
        Some("mult") => commands::mult(args),
        Some("mr-ata") => commands::mr_ata(args),
        Some("simulate") => commands::simulate(args),
        Some("worker") => commands::worker(args),
        Some("serve") => crate::serve::http::serve(args),
        Some("update") => commands::update(args),
        Some("daemon") => crate::daemon::server::daemon(args),
        Some("daemon-client") => crate::daemon::server::daemon_client(args),
        Some("serve-metrics") => server::serve_metrics(args),
        Some("trace-summary") => commands::trace_summary(args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(Error::Config(format!(
            "unknown command `{other}` (run `tallfat help`)"
        ))),
    }
}

fn parse_level(s: &str) -> crate::util::logger::Level {
    use crate::util::logger::Level;
    match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_command_errors() {
        let args = Args::parse(["frobnicate".to_string()]).unwrap();
        assert!(run_cli(&args).is_err());
    }

    #[test]
    fn help_succeeds() {
        let args = Args::parse(["help".to_string()]).unwrap();
        run_cli(&args).unwrap();
    }

    #[test]
    fn no_command_prints_usage() {
        let args = Args::parse(Vec::<String>::new()).unwrap();
        run_cli(&args).unwrap();
    }
}
