//! Run configuration: defaults < config file < CLI overrides.
//!
//! The file format is a minimal TOML subset (`[section]`, `key = value`,
//! `#` comments) parsed by [`parser`] — serde/toml are unavailable offline.

pub mod parser;

use crate::error::{Error, Result};
use crate::util::Args;
use parser::ConfigFile;

/// Which block-compute backend executes the per-block math.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-rust linalg (any shape).
    Native,
    /// AOT-compiled XLA artifacts via PJRT (fixed shapes, padded).
    Xla,
    /// XLA where an artifact exists, native otherwise.
    Auto,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(BackendKind::Native),
            "xla" => Ok(BackendKind::Xla),
            "auto" => Ok(BackendKind::Auto),
            other => Err(Error::Config(format!("unknown backend `{other}`"))),
        }
    }
}

/// Input file format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputFormat {
    /// `;`-separated text rows (the paper's format).
    Csv,
    /// tallfat binary matrix (`io::binmat`).
    Bin,
    /// libsvm sparse text: `[label] idx:val idx:val ...`, 1-based indices
    /// (`io::sparse`).
    Libsvm,
    /// `;`-separated sparse text: `idx:val;idx:val`, 0-based indices
    /// (`io::sparse`).
    SparseCsv,
    /// tallfat binary CSR shard (`io::sparse`).
    Csr,
}

impl InputFormat {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "csv" => Ok(InputFormat::Csv),
            "bin" => Ok(InputFormat::Bin),
            "libsvm" | "svm" => Ok(InputFormat::Libsvm),
            "sparse-csv" | "scsv" => Ok(InputFormat::SparseCsv),
            "csr" => Ok(InputFormat::Csr),
            other => Err(Error::Config(format!("unknown format `{other}`"))),
        }
    }

    /// Guess from a file extension.
    pub fn from_path(path: &str) -> Self {
        if path.ends_with(".bin") || path.ends_with(".tfb") {
            InputFormat::Bin
        } else if path.ends_with(".libsvm") || path.ends_with(".svm") {
            InputFormat::Libsvm
        } else if path.ends_with(".scsv") {
            InputFormat::SparseCsv
        } else if path.ends_with(".csr") {
            InputFormat::Csr
        } else {
            InputFormat::Csv
        }
    }

    /// Whether rows are stored as (index, value) pairs rather than dense.
    pub fn is_sparse(self) -> bool {
        matches!(self, InputFormat::Libsvm | InputFormat::SparseCsv | InputFormat::Csr)
    }
}

/// Full run configuration for the coordinator.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Input matrix path.
    pub input: String,
    pub format: InputFormat,
    /// Target rank of the factorization.
    pub k: usize,
    /// Oversampling columns added to the sketch (Halko's p; total sketch
    /// width is `k + oversample`).
    pub oversample: usize,
    /// Power-iteration count (0 = paper's plain sketch).
    pub power_iters: usize,
    /// Split-Process worker count.
    pub workers: usize,
    /// Row-block size fed to the block backend.
    pub block: usize,
    /// PRNG seed for the virtual Ω.
    pub seed: u64,
    pub backend: BackendKind,
    /// Directory holding AOT artifacts + manifest.
    pub artifacts_dir: String,
    /// Directory for Y/U shards and outputs.
    pub work_dir: String,
    /// Compute right singular vectors V (adds the pass-2 W accumulation).
    pub compute_v: bool,
    /// Skip the projection and eigendecompose `A^T A` directly (small n).
    pub exact_gram: bool,
    /// PCA mode: subtract per-column means before factorizing.
    pub center: bool,
    /// Format of the Y/U0/U intermediate shards (Bin is faster; Csv matches
    /// the paper's artifacts and is human-inspectable).
    pub shard_format: InputFormat,
    /// Relative cutoff for the sketch-stage guarded inverse `M = V_y Σ_y⁻¹`.
    pub sigma_cutoff_rel: f64,
    /// Rows per scheduler chunk; 0 (default) derives the chunk count from
    /// `chunks_per_worker` instead.
    pub chunk_rows: usize,
    /// Chunks planned per worker when `chunk_rows = 0`; 1 reproduces the
    /// old static one-chunk-per-worker schedule.
    pub chunks_per_worker: usize,
    /// Retry budget per chunk before a pass fails.
    pub chunk_retries: usize,
    /// Pin the column count for sparse inputs (libsvm/sparse-CSV/CSR),
    /// whose scans otherwise derive n from the max index seen — an
    /// undershoot when a batch happens to omit the tail columns. 0 (the
    /// default) keeps the derived width; chained `update` batches should
    /// pin the base model's n so every batch agrees.
    pub cols: usize,
    /// Target relative residual for the adaptive streaming route
    /// (`tallfat stream`): the sketch widens until the a posteriori
    /// residual estimate drops below `tol`. Must be positive and finite.
    pub tol: f64,
    /// Rank ceiling for the adaptive streaming route (0 = the stream
    /// default). When set it must be >= `k`.
    pub max_rank: usize,
    /// Rows absorbed per streaming batch (`tallfat stream`).
    pub batch_rows: usize,
    /// Partial-reduction topology: `tree` (default — the distributed
    /// pairwise merge schedule) or `star` (sequential leader-side fold).
    pub reduce: crate::svd::ReduceMode,
    /// Row-band height for the tall `W` reduction (0 = auto from sketch
    /// width).
    pub band_rows: usize,
    /// Re-plan chunk granularity between passes from measured chunk wall
    /// times (`--no-adaptive-chunks` turns it off; an explicit
    /// `chunk_rows` always wins).
    pub adaptive_chunks: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            input: String::new(),
            format: InputFormat::Csv,
            k: 16,
            oversample: 8,
            power_iters: 0,
            workers: 4,
            block: 256,
            seed: 0,
            backend: BackendKind::Native,
            artifacts_dir: "artifacts".into(),
            work_dir: std::env::temp_dir().join("tallfat").to_string_lossy().into_owned(),
            compute_v: true,
            exact_gram: false,
            center: false,
            shard_format: InputFormat::Bin,
            sigma_cutoff_rel: crate::svd::DEFAULT_SIGMA_CUTOFF_REL,
            chunk_rows: 0,
            chunks_per_worker: crate::splitproc::sched::DEFAULT_CHUNKS_PER_WORKER,
            chunk_retries: crate::splitproc::sched::DEFAULT_CHUNK_RETRIES,
            cols: 0,
            tol: crate::stream::DEFAULT_TOL,
            max_rank: 0,
            batch_rows: crate::stream::DEFAULT_BATCH_ROWS,
            reduce: crate::svd::ReduceMode::default(),
            band_rows: 0,
            adaptive_chunks: true,
        }
    }
}

impl RunConfig {
    /// Total sketch width `k + oversample`.
    pub fn sketch_width(&self) -> usize {
        self.k + self.oversample
    }

    /// Apply a parsed config file's `[svd]` / `[run]` sections.
    pub fn apply_file(&mut self, file: &ConfigFile) -> Result<()> {
        for section in ["run", "svd"] {
            if let Some(k) = file.get_usize(section, "k")? {
                self.k = k;
            }
            if let Some(v) = file.get_usize(section, "oversample")? {
                self.oversample = v;
            }
            if let Some(v) = file.get_usize(section, "power_iters")? {
                self.power_iters = v;
            }
            if let Some(v) = file.get_usize(section, "workers")? {
                self.workers = v;
            }
            if let Some(v) = file.get_usize(section, "block")? {
                self.block = v;
            }
            if let Some(v) = file.get_u64(section, "seed")? {
                self.seed = v;
            }
            if let Some(v) = file.get_str(section, "backend") {
                self.backend = BackendKind::parse(v)?;
            }
            if let Some(v) = file.get_str(section, "input") {
                self.input = v.to_string();
                self.format = InputFormat::from_path(&self.input);
            }
            if let Some(v) = file.get_str(section, "format") {
                self.format = InputFormat::parse(v)?;
            }
            if let Some(v) = file.get_str(section, "input_format") {
                self.format = InputFormat::parse(v)?;
            }
            if let Some(v) = file.get_str(section, "artifacts_dir") {
                self.artifacts_dir = v.to_string();
            }
            if let Some(v) = file.get_str(section, "work_dir") {
                self.work_dir = v.to_string();
            }
            if let Some(v) = file.get_bool(section, "compute_v")? {
                self.compute_v = v;
            }
            if let Some(v) = file.get_bool(section, "exact_gram")? {
                self.exact_gram = v;
            }
            if let Some(v) = file.get_bool(section, "center")? {
                self.center = v;
            }
            if let Some(v) = file.get_str(section, "shard_format") {
                self.shard_format = InputFormat::parse(v)?;
            }
            if let Some(v) = file.get_f64(section, "sigma_cutoff_rel")? {
                self.sigma_cutoff_rel = v;
            }
            if let Some(v) = file.get_usize(section, "chunk_rows")? {
                self.chunk_rows = v;
            }
            if let Some(v) = file.get_usize(section, "chunks_per_worker")? {
                self.chunks_per_worker = v;
            }
            if let Some(v) = file.get_usize(section, "chunk_retries")? {
                self.chunk_retries = v;
            }
            if let Some(v) = file.get_usize(section, "cols")? {
                self.cols = v;
            }
            if let Some(v) = file.get_f64(section, "tol")? {
                self.tol = v;
            }
            if let Some(v) = file.get_usize(section, "max_rank")? {
                self.max_rank = v;
            }
            if let Some(v) = file.get_usize(section, "batch_rows")? {
                self.batch_rows = v;
            }
            if let Some(v) = file.get_str(section, "reduce") {
                self.reduce = crate::svd::ReduceMode::parse(v)?;
            }
            if let Some(v) = file.get_usize(section, "band_rows")? {
                self.band_rows = v;
            }
            if let Some(v) = file.get_bool(section, "adaptive_chunks")? {
                self.adaptive_chunks = v;
            }
        }
        Ok(())
    }

    /// Apply CLI overrides (highest precedence).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(input) = args.opt_str("input") {
            self.input = input.to_string();
            self.format = InputFormat::from_path(&self.input);
        } else if let Some(first) = args.positional.first() {
            self.input = first.clone();
            self.format = InputFormat::from_path(&self.input);
        }
        self.k = args.usize_or("k", self.k)?;
        self.oversample = args.usize_or("oversample", self.oversample)?;
        self.power_iters = args.usize_or("power-iters", self.power_iters)?;
        self.workers = args.usize_or("workers", self.workers)?;
        self.block = args.usize_or("block", self.block)?;
        self.seed = args.u64_or("seed", self.seed)?;
        if let Some(b) = args.opt_str("backend") {
            self.backend = BackendKind::parse(b)?;
        }
        if let Some(f) = args.opt_str("format") {
            self.format = InputFormat::parse(f)?;
        }
        if let Some(f) = args.opt_str("input-format") {
            self.format = InputFormat::parse(f)?;
        }
        if let Some(d) = args.opt_str("artifacts-dir") {
            self.artifacts_dir = d.to_string();
        }
        if let Some(d) = args.opt_str("work-dir") {
            self.work_dir = d.to_string();
        }
        if args.flag("no-v") {
            self.compute_v = false;
        }
        if args.flag("exact-gram") {
            self.exact_gram = true;
        }
        if args.flag("center") {
            self.center = true;
        }
        if let Some(f) = args.opt_str("shard-format") {
            self.shard_format = InputFormat::parse(f)?;
        }
        self.sigma_cutoff_rel = args.f64_or("sigma-cutoff", self.sigma_cutoff_rel)?;
        self.chunk_rows = args.usize_or("chunk-rows", self.chunk_rows)?;
        self.chunks_per_worker = args.usize_or("chunks-per-worker", self.chunks_per_worker)?;
        self.chunk_retries = args.usize_or("chunk-retries", self.chunk_retries)?;
        self.cols = args.usize_or("cols", self.cols)?;
        self.tol = args.f64_or("tol", self.tol)?;
        self.max_rank = args.usize_or("max-rank", self.max_rank)?;
        self.batch_rows = args.usize_or("batch-rows", self.batch_rows)?;
        if let Some(r) = args.opt_str("reduce") {
            self.reduce = crate::svd::ReduceMode::parse(r)?;
        }
        self.band_rows = args.usize_or("band-rows", self.band_rows)?;
        if args.flag("no-adaptive-chunks") {
            self.adaptive_chunks = false;
        }
        Ok(())
    }

    /// The [`crate::svd::SvdOptions`] view of this config — the single
    /// source for the field mapping (used by the `Svd` builder and by
    /// [`RunConfig::validate`]).
    pub fn svd_options(&self) -> crate::svd::SvdOptions {
        crate::svd::SvdOptions {
            k: self.k,
            oversample: self.oversample,
            power_iters: self.power_iters,
            workers: self.workers,
            block: self.block,
            seed: self.seed,
            work_dir: self.work_dir.clone(),
            compute_v: self.compute_v,
            shard_format: self.shard_format,
            center: self.center,
            exact_gram: self.exact_gram,
            sigma_cutoff_rel: self.sigma_cutoff_rel,
            chunk_rows: self.chunk_rows,
            chunks_per_worker: self.chunks_per_worker,
            chunk_retries: self.chunk_retries,
            tol: self.tol,
            reduce: self.reduce,
            band_rows: self.band_rows,
            adaptive_chunks: self.adaptive_chunks,
            // The coordinator's result paths (save/serve/report) read a
            // dense V; cap-constrained callers opt out via the builder.
            materialize_v: true,
        }
    }

    /// Validate invariants before a run. Numeric invariants are checked by
    /// [`crate::svd::SvdOptions::validate`] — one copy, shared with the
    /// fluent builder path; the evenness rule on `block` (XLA artifact
    /// shape alignment) stays a CLI/config-level constraint only.
    pub fn validate(&self) -> Result<()> {
        if self.input.is_empty() {
            return Err(Error::Config("no input file (use --input or positional)".into()));
        }
        if self.block % 2 != 0 {
            return Err(Error::Config(format!(
                "block must be a positive even size, got {}",
                self.block
            )));
        }
        if self.max_rank != 0 && self.max_rank < self.k {
            return Err(Error::Config(format!(
                "max_rank ({}) must be >= k ({})",
                self.max_rank, self.k
            )));
        }
        if self.batch_rows == 0 {
            return Err(Error::Config("batch_rows must be >= 1".into()));
        }
        self.svd_options().validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate_with_input() {
        let mut c = RunConfig::default();
        assert!(c.validate().is_err());
        c.input = "a.csv".into();
        assert!(c.validate().is_ok());
        assert_eq!(c.sketch_width(), 24);
    }

    #[test]
    fn args_override() {
        let args = Args::parse(
            "svd data.bin --k 32 --workers 8 --backend xla --seed 7"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.input, "data.bin");
        assert_eq!(c.format, InputFormat::Bin);
        assert_eq!(c.k, 32);
        assert_eq!(c.workers, 8);
        assert_eq!(c.backend, BackendKind::Xla);
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn file_then_args_precedence() {
        let file = ConfigFile::parse_str(
            "[svd]\nk = 8\nworkers = 2\nbackend = \"native\"\n",
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.apply_file(&file).unwrap();
        assert_eq!(c.k, 8);
        let args =
            Args::parse("svd --k 64".split_whitespace().map(String::from)).unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.k, 64); // CLI wins
        assert_eq!(c.workers, 2); // file survives where CLI silent
    }

    #[test]
    fn bad_backend_rejected() {
        assert!(BackendKind::parse("gpu").is_err());
    }

    #[test]
    fn shard_format_and_sigma_cutoff_parse() {
        let file = ConfigFile::parse_str(
            "[svd]\nshard_format = \"csv\"\nsigma_cutoff_rel = 1e-5\n",
        )
        .unwrap();
        let mut c = RunConfig::default();
        assert_eq!(c.shard_format, InputFormat::Bin);
        c.apply_file(&file).unwrap();
        assert_eq!(c.shard_format, InputFormat::Csv);
        assert!((c.sigma_cutoff_rel - 1e-5).abs() < 1e-18);
        // CLI overrides the file.
        let args = Args::parse(
            "svd a.csv --shard-format bin --sigma-cutoff 1e-4"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.shard_format, InputFormat::Bin);
        assert!((c.sigma_cutoff_rel - 1e-4).abs() < 1e-18);
        // Out-of-range cutoff rejected.
        c.sigma_cutoff_rel = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn chunk_knobs_parse_from_file_and_cli() {
        let file = ConfigFile::parse_str(
            "[svd]\nchunk_rows = 5000\nchunks_per_worker = 8\nchunk_retries = 1\n",
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.apply_file(&file).unwrap();
        assert_eq!(c.chunk_rows, 5000);
        assert_eq!(c.chunks_per_worker, 8);
        assert_eq!(c.chunk_retries, 1);
        let args = Args::parse(
            "svd a.csv --chunk-rows 0 --chunks-per-worker 2 --chunk-retries 3"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.chunk_rows, 0);
        assert_eq!(c.chunks_per_worker, 2);
        assert_eq!(c.chunk_retries, 3);
        // The scheduler policy view maps 1:1.
        let p = c.svd_options().sched_policy();
        assert_eq!(p.chunks_per_worker, 2);
        assert_eq!(p.max_retries, 3);
        // chunks_per_worker = 0 is rejected.
        c.chunks_per_worker = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn reduce_knobs_parse_from_file_and_cli() {
        use crate::svd::ReduceMode;
        let file = ConfigFile::parse_str(
            "[svd]\nreduce = \"star\"\nband_rows = 4096\nadaptive_chunks = false\n",
        )
        .unwrap();
        let mut c = RunConfig::default();
        assert_eq!(c.reduce, ReduceMode::Tree);
        assert!(c.adaptive_chunks);
        c.apply_file(&file).unwrap();
        assert_eq!(c.reduce, ReduceMode::Star);
        assert_eq!(c.band_rows, 4096);
        assert!(!c.adaptive_chunks);
        let args = Args::parse(
            "svd a.csv --reduce tree --band-rows 512"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.reduce, ReduceMode::Tree);
        assert_eq!(c.band_rows, 512);
        let o = c.svd_options();
        assert_eq!(o.reduce, ReduceMode::Tree);
        assert_eq!(o.band_rows, 512);
        assert!(!o.adaptive_chunks);
        // --no-adaptive-chunks is a one-way CLI switch.
        let args = Args::parse(
            "svd a.csv --no-adaptive-chunks".split_whitespace().map(String::from),
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.apply_args(&args).unwrap();
        assert!(!c.adaptive_chunks);
        assert!(crate::svd::ReduceMode::parse("ring").is_err());
    }

    #[test]
    fn cols_pin_parses_from_file_and_cli() {
        let file = ConfigFile::parse_str("[svd]\ncols = 500\n").unwrap();
        let mut c = RunConfig::default();
        assert_eq!(c.cols, 0);
        c.apply_file(&file).unwrap();
        assert_eq!(c.cols, 500);
        let args = Args::parse(
            "svd a.libsvm --cols 1000".split_whitespace().map(String::from),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.cols, 1000);
    }

    #[test]
    fn format_guessing() {
        assert_eq!(InputFormat::from_path("x.bin"), InputFormat::Bin);
        assert_eq!(InputFormat::from_path("x.csv"), InputFormat::Csv);
        assert_eq!(InputFormat::from_path("x.txt"), InputFormat::Csv);
        assert_eq!(InputFormat::from_path("x.libsvm"), InputFormat::Libsvm);
        assert_eq!(InputFormat::from_path("x.svm"), InputFormat::Libsvm);
        assert_eq!(InputFormat::from_path("x.scsv"), InputFormat::SparseCsv);
        assert_eq!(InputFormat::from_path("x.csr"), InputFormat::Csr);
    }

    #[test]
    fn sparse_formats_parse_and_flag() {
        assert_eq!(InputFormat::parse("libsvm").unwrap(), InputFormat::Libsvm);
        assert_eq!(InputFormat::parse("sparse-csv").unwrap(), InputFormat::SparseCsv);
        assert_eq!(InputFormat::parse("csr").unwrap(), InputFormat::Csr);
        assert!(InputFormat::Libsvm.is_sparse());
        assert!(InputFormat::Csr.is_sparse());
        assert!(!InputFormat::Csv.is_sparse());
        assert!(!InputFormat::Bin.is_sparse());
    }

    #[test]
    fn input_format_flag_overrides_extension() {
        // `--input-format libsvm` beats the `.data` extension guess.
        let args = Args::parse(
            "svd ratings.data --input-format libsvm"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.format, InputFormat::Libsvm);
        // A sparse *shard* format is rejected at validation time.
        c.shard_format = InputFormat::Csr;
        assert!(c.validate().is_err());
        c.shard_format = InputFormat::Bin;
        assert!(c.validate().is_ok());
    }
}
