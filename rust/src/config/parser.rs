//! Minimal TOML-subset parser.
//!
//! Supported: `[section]` headers, `key = value` lines, `#` comments, and
//! values of kind string (`"..."`), integer, float, and bool. Enough for
//! run configs; deliberately not a full TOML implementation.

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Parsed config file: `(section, key) -> raw value string`.
#[derive(Debug, Default, Clone)]
pub struct ConfigFile {
    values: HashMap<(String, String), String>,
}

impl ConfigFile {
    /// Parse from a string.
    pub fn parse_str(text: &str) -> Result<Self> {
        let mut out = ConfigFile::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| Error::parse(format!("line {}: unterminated section", lineno + 1)))?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(Error::parse(format!("line {}: empty section name", lineno + 1)));
                }
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| Error::parse(format!("line {}: expected key = value", lineno + 1)))?;
            let key = k.trim();
            if key.is_empty() {
                return Err(Error::parse(format!("line {}: empty key", lineno + 1)));
            }
            out.values
                .insert((section.clone(), key.to_string()), v.trim().to_string());
        }
        Ok(out)
    }

    /// Parse from a file path.
    pub fn parse_file(path: &str) -> Result<Self> {
        Self::parse_str(&std::fs::read_to_string(path)?)
    }

    fn raw(&self, section: &str, key: &str) -> Option<&str> {
        self.values
            .get(&(section.to_string(), key.to_string()))
            .map(String::as_str)
    }

    /// String value (quotes stripped if present).
    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.raw(section, key).map(|v| {
            v.strip_prefix('"')
                .and_then(|s| s.strip_suffix('"'))
                .unwrap_or(v)
        })
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Result<Option<usize>> {
        self.parse_with(section, key, "integer", |s| s.parse::<usize>().ok())
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Result<Option<u64>> {
        self.parse_with(section, key, "integer", |s| s.parse::<u64>().ok())
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Result<Option<f64>> {
        self.parse_with(section, key, "float", |s| s.parse::<f64>().ok())
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>> {
        self.parse_with(section, key, "bool", |s| match s {
            "true" => Some(true),
            "false" => Some(false),
            _ => None,
        })
    }

    fn parse_with<T>(
        &self,
        section: &str,
        key: &str,
        kind: &str,
        f: impl Fn(&str) -> Option<T>,
    ) -> Result<Option<T>> {
        match self.raw(section, key) {
            None => Ok(None),
            Some(v) => f(v).map(Some).ok_or_else(|| {
                Error::parse(format!("[{section}] {key}: expected {kind}, got `{v}`"))
            }),
        }
    }

    /// All keys of a section (for diagnostics).
    pub fn section_keys(&self, section: &str) -> Vec<&str> {
        let mut keys: Vec<&str> = self
            .values
            .keys()
            .filter(|(s, _)| s == section)
            .map(|(_, k)| k.as_str())
            .collect();
        keys.sort();
        keys
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside quotes is content, not a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top comment
[svd]
k = 16
oversample = 8
backend = "xla"   # inline comment
tol = 0.5
verbose = true
name = "has # hash"

[cluster]
nodes = 4
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = ConfigFile::parse_str(SAMPLE).unwrap();
        assert_eq!(c.get_usize("svd", "k").unwrap(), Some(16));
        assert_eq!(c.get_str("svd", "backend"), Some("xla"));
        assert_eq!(c.get_f64("svd", "tol").unwrap(), Some(0.5));
        assert_eq!(c.get_bool("svd", "verbose").unwrap(), Some(true));
        assert_eq!(c.get_usize("cluster", "nodes").unwrap(), Some(4));
    }

    #[test]
    fn missing_returns_none() {
        let c = ConfigFile::parse_str(SAMPLE).unwrap();
        assert_eq!(c.get_usize("svd", "nope").unwrap(), None);
        assert_eq!(c.get_str("other", "k"), None);
    }

    #[test]
    fn type_errors_reported() {
        let c = ConfigFile::parse_str("[a]\nx = hello\n").unwrap();
        assert!(c.get_usize("a", "x").is_err());
    }

    #[test]
    fn hash_inside_quotes_kept() {
        let c = ConfigFile::parse_str(SAMPLE).unwrap();
        assert_eq!(c.get_str("svd", "name"), Some("has # hash"));
    }

    #[test]
    fn malformed_lines_error() {
        assert!(ConfigFile::parse_str("[unclosed\n").is_err());
        assert!(ConfigFile::parse_str("[a]\njust a line\n").is_err());
        assert!(ConfigFile::parse_str("[]\n").is_err());
    }

    #[test]
    fn section_keys_sorted() {
        let c = ConfigFile::parse_str("[s]\nb = 1\na = 2\n").unwrap();
        assert_eq!(c.section_keys("s"), vec!["a", "b"]);
    }
}
