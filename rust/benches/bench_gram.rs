//! E5 — Gram-computation paths (paper §2.0.2): row outer products vs
//! blocked SYRK vs the AOT XLA artifact, plus equivalence to a full matmul.
//!
//! The paper's identity `A^T A = Σ_i A_i ⊗ A_i` makes the computation
//! streaming-friendly; this bench shows all paths agree to fp tolerance and
//! measures their throughput (GFLOP/s at 2·m·n² flops).

mod common;

use tallfat::backend::{xla::XlaBackend, Backend};
use tallfat::backend::native::NativeBackend;
use tallfat::linalg::{gram, gram_outer, matmul, Matrix};
use tallfat::rng::Gaussian;

fn gflops(m: usize, n: usize, t: std::time::Duration) -> f64 {
    2.0 * m as f64 * n as f64 * n as f64 / t.as_secs_f64() / 1e9
}

fn main() {
    let native = NativeBackend::new();
    let xla = XlaBackend::start("artifacts", false).ok();
    if xla.is_none() {
        eprintln!("[warn] artifacts/ missing — xla rows skipped (run `make artifacts`)");
    }

    for n in [64usize, 256] {
        let m = 50_000;
        common::header(&format!("E5 gram paths — m={m} n={n} (f64 native, f32 artifact)"));
        let g = Gaussian::new(3);
        let a = Matrix::from_fn(m, n, |i, j| g.sample(i as u64, j as u64));

        // Reference: full matmul A^T · A.
        let at = a.t();
        let (g_mm, t_mm) = common::time_best(2, || matmul(&at, &a).unwrap());

        // Row outer products (paper-literal).
        let (g_outer, t_outer) = common::time_best(2, || gram_outer(&a));

        // Blocked SYRK (native backend hot path).
        let (g_syrk, t_syrk) = common::time_best(2, || gram(&a));

        println!(
            "{:<26} {:>12} {:>10} {:>12}",
            "path", "time", "GFLOP/s", "max|ΔG|"
        );
        println!(
            "{:<26} {:>12.2?} {:>10.2} {:>12}",
            "matmul A^T·A (ref)", t_mm, gflops(m, n, t_mm), "0"
        );
        println!(
            "{:<26} {:>12.2?} {:>10.2} {:>12.1e}",
            "row outer products", t_outer, gflops(m, n, t_outer), g_outer.max_abs_diff(&g_mm)
        );
        println!(
            "{:<26} {:>12.2?} {:>10.2} {:>12.1e}",
            "blocked syrk", t_syrk, gflops(m, n, t_syrk), g_syrk.max_abs_diff(&g_mm)
        );

        // XLA artifact: fixed 256-row blocks, accumulate over blocks.
        if let Some(x) = &xla {
            let run_xla = || {
                let mut acc = Matrix::zeros(n, n);
                let mut i = 0;
                while i < m {
                    let hi = (i + 256).min(m);
                    let block = a.slice_rows(i, hi);
                    acc.add_assign(&x.gram_block(&block).unwrap()).unwrap();
                    i = hi;
                }
                acc
            };
            let (g_xla, t_xla) = common::time_best(2, run_xla);
            println!(
                "{:<26} {:>12.2?} {:>10.2} {:>12.1e}",
                "xla artifact (f32)", t_xla, gflops(m, n, t_xla),
                g_xla.max_abs_diff(&g_mm)
            );
        }
        let _ = &native;
    }
    println!(
        "\nshape check: all paths agree (f64 to ~1e-9, f32 artifact to ~1e-2\n\
         absolute at these magnitudes); blocked > outer in throughput."
    );
}
