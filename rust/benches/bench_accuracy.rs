//! E4 — Johnson–Lindenstrauss distortion and sketch accuracy (paper §2.0.3).
//!
//! Two series:
//!
//! * **E4.a distortion vs k** — project clustered "document" rows to k
//!   dimensions; mean/max pairwise-distance distortion should shrink like
//!   `1/sqrt(k)` (the JL bound `k = O(log n / ε²)` inverted).
//! * **E4.b rank-k reconstruction vs exact SVD** — randomized rank-k SVD
//!   error vs the optimal (exact truncated-SVD tail energy), over spectrum
//!   shapes: fast geometric decay (sketching's sweet spot), slow power-law
//!   decay (hard case), and the effect of power iterations on the hard case.

mod common;

use std::sync::Arc;
use tallfat::backend::native::NativeBackend;
use tallfat::io::dataset::{gen_clustered, gen_exact, Spectrum};
use tallfat::io::InputSpec;
use tallfat::linalg::Matrix;
use tallfat::rng::VirtualMatrix;
use tallfat::svd::validate::{distance_distortion, reconstruction_error_streaming};
use tallfat::svd::Svd;

fn project(a: &Matrix, k: usize, seed: u64) -> Matrix {
    let vm = VirtualMatrix::projection(seed, a.cols(), k);
    let omega = vm.materialize();
    tallfat::linalg::matmul(a, &omega).unwrap()
}

fn main() {
    let dir = common::bench_dir("accuracy");
    let backend = Arc::new(NativeBackend::new());

    // ---- E4.a: JL distortion vs k -----------------------------------------
    common::header("E4.a pairwise-distance distortion vs k (2000x512 clustered, 2000 pairs)");
    let (a, _) = gen_clustered(2000, 512, 16, 1.0, 11);
    println!(
        "{:>6} {:>12} {:>12} {:>16}",
        "k", "mean dist", "max dist", "mean·sqrt(k)"
    );
    for k in [4usize, 8, 16, 32, 64, 128, 256] {
        let y = project(&a, k, 1);
        let (mean, max) = distance_distortion(&a, &y, 2000, 77);
        println!("{:>6} {:>12.4} {:>12.4} {:>16.3}", k, mean, max, mean * (k as f64).sqrt());
    }
    println!("(constant right column = the 1/sqrt(k) JL shape)");

    // ---- E4.b: randomized SVD accuracy vs the optimum ----------------------
    let m = 1500;
    let n = 256;
    let rank = 64;
    for (label, spectrum, powers) in [
        ("geometric decay 0.8 (easy)", Spectrum::Geometric { scale: 10.0, decay: 0.8 }, vec![0]),
        ("power-law 1/(1+i) (hard)", Spectrum::Power { scale: 10.0 }, vec![0, 1, 2]),
    ] {
        common::header(&format!("E4.b rank-k error vs exact — {label} ({m}x{n}, true rank {rank})"));
        let (a, sigma) = gen_exact(m, n, rank, spectrum, 0.0, 5).unwrap();
        let input = InputSpec::csv(
            dir.join(format!("acc_{}.csv", label.as_bytes()[0] as char))
                .to_string_lossy()
                .into_owned(),
        );
        tallfat::io::write_matrix(&a, &input).unwrap();
        let total: f64 = sigma.iter().map(|s| s * s).sum::<f64>();

        print!("{:>6} {:>14}", "k", "optimal");
        for q in &powers {
            print!(" {:>14}", format!("sketch q={q}"));
        }
        println!();
        for k in [4usize, 8, 16, 32, 64] {
            // Optimal rank-k error = tail energy of the true spectrum.
            let tail: f64 = sigma[k.min(rank)..].iter().map(|s| s * s).sum::<f64>();
            print!("{:>6} {:>14.6}", k, (tail / total).sqrt());
            for &q in &powers {
                let res = Svd::over(&input)
                    .unwrap()
                    .rank(k)
                    .oversample(8)
                    .power_iters(q)
                    .workers(2)
                    .seed(9)
                    .work_dir(dir.join(format!("w_{k}_{q}")).to_string_lossy().into_owned())
                    .backend(backend.clone())
                    .run()
                    .unwrap();
                let err = reconstruction_error_streaming(&input, &res).unwrap();
                print!(" {:>14.6}", err);
            }
            println!();
        }
    }
    println!(
        "\nshape check: sketch ≈ optimal for geometric decay; gap on power-law\n\
         closes with power iterations (Halko-style extension, DESIGN.md §svd)."
    );
}
