//! E9 — ablations of the design choices DESIGN.md calls out.
//!
//! * **E9.a Gram route vs TSQR** (paper §2.0.1 vs its reference [1]):
//!   `AᵀA` squares the condition number; sweep the spectrum span and show
//!   where the paper's route loses σ_min while streaming TSQR holds it.
//! * **E9.b oversampling** (Halko's p): accuracy vs the sketch-width tax.
//! * **E9.c fused vs separate pass 1**: the fused project+gram artifact
//!   against running project then gram as two ops (why L1 fuses them).
//! * **E9.d shard format**: CSV vs binary intermediates on the pipeline.

mod common;

use std::sync::Arc;
use tallfat::backend::{native::NativeBackend, Backend};
use tallfat::config::InputFormat;
use tallfat::io::dataset::{gen_exact, Spectrum};
use tallfat::io::InputSpec;
use tallfat::jobs::tsqr_sigma_file;
use tallfat::linalg::{eigen::eigh, gram, Matrix};
use tallfat::rng::Gaussian;
use tallfat::svd::{validate::reconstruction_error_streaming, Svd};

fn main() {
    let dir = common::bench_dir("ablation");
    let backend = Arc::new(NativeBackend::new());

    // ---- E9.a conditioning: gram vs tsqr ------------------------------------
    common::header("E9.a sigma_min recovery vs condition number (m=2000 n=12)");
    println!(
        "{:>10} {:>12} {:>16} {:>16}",
        "kappa", "sigma_min", "gram rel err", "tsqr rel err"
    );
    for (kappa, decay) in [(1e2, 0.657), (1e4, 0.433), (1e6, 0.285), (1e8, 0.187)] {
        let n = 12;
        let (a, _) =
            gen_exact(2000, n, n, Spectrum::Geometric { scale: 1.0, decay }, 0.0, 31).unwrap();
        // Ground truth = the matrix's actual spectrum (dense one-sided
        // Jacobi SVD, accurate to machine precision for small n) — the
        // generator's declared sigma has its own f64 construction floor.
        let smin = tallfat::linalg::exact_svd(&a).unwrap().sigma[n - 1];
        let input = InputSpec::bin(
            dir.join(format!("cond_{}.bin", kappa as u64)).to_string_lossy().into_owned(),
        );
        tallfat::io::write_matrix(&a, &input).unwrap();
        // gram route
        let g = gram(&a);
        let (w, _) = eigh(&g).unwrap();
        let gram_smin = w[n - 1].max(0.0).sqrt();
        // tsqr route (streaming over the file)
        let tsqr_sigma = tsqr_sigma_file(&input, 3, 128).unwrap();
        println!(
            "{:>10.0e} {:>12.3e} {:>16.2e} {:>16.2e}",
            kappa,
            smin,
            (gram_smin - smin).abs() / smin,
            (tsqr_sigma[n - 1] - smin).abs() / smin
        );
    }
    println!("(gram squares kappa: sigma_min drowns past kappa ~ 1e8 = sqrt(1/eps_f64))");

    // ---- E9.b oversampling ----------------------------------------------------
    common::header("E9.b oversampling p at k=16 (power-law spectrum, 1500x256)");
    let (a, _) = gen_exact(1500, 256, 64, Spectrum::Power { scale: 10.0 }, 0.0, 32).unwrap();
    let input = InputSpec::bin(dir.join("oversample.bin").to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a, &input).unwrap();
    println!("{:>6} {:>10} {:>14} {:>12}", "p", "sketch", "recon err", "time");
    for p in [0usize, 2, 4, 8, 16, 32] {
        let (res, t) = common::time_once(|| {
            Svd::over(&input)
                .unwrap()
                .rank(16)
                .oversample(p)
                .workers(2)
                .seed(9)
                .work_dir(dir.join(format!("os{p}")).to_string_lossy().into_owned())
                .backend(backend.clone())
                .run()
                .unwrap()
        });
        let err = reconstruction_error_streaming(&input, &res).unwrap();
        println!("{:>6} {:>10} {:>14.6} {:>12.2?}", p, 16 + p, err, t);
    }
    println!("(optimal rank-16 error here = 0.166; p>=8 buys most of the gap)");

    // ---- E9.c fused vs separate pass-1 -----------------------------------------
    common::header("E9.c fused project+gram vs separate ops (per 256-row block, best of 20)");
    let g = Gaussian::new(33);
    println!("{:<8} {:>14} {:>16} {:>8}", "n", "separate", "fused", "ratio");
    for n in [256usize, 1024, 2048] {
        let x = Matrix::from_fn(256, n, |i, j| g.sample(i as u64, j as u64));
        let w = Matrix::from_fn(n, 32, |i, j| g.sample(1000 + i as u64, j as u64));
        let (_, t_sep) = common::time_best(20, || {
            let y = backend.project_block(&x, &w).unwrap();
            backend.gram_block(&y).unwrap()
        });
        let (_, t_fused) = common::time_best(20, || backend.project_gram_block(&x, &w).unwrap());
        println!(
            "{:<8} {:>14.1?} {:>16.1?} {:>7.2}x",
            n,
            t_sep,
            t_fused,
            t_sep.as_secs_f64() / t_fused.as_secs_f64()
        );
    }

    // ---- E9.d shard format -------------------------------------------------------
    common::header("E9.d Y/U shard format: csv vs bin (20000x256 pipeline, k=16)");
    let sh_input = common::ensure_dataset(&dir, "shards", 20_000, 256, true);
    println!("{:>8} {:>12} {:>14}", "format", "end-to-end", "Y shard bytes");
    for (label, fmt) in [("bin", InputFormat::Bin), ("csv", InputFormat::Csv)] {
        let (res, t) = common::time_once(|| {
            Svd::over(&sh_input)
                .unwrap()
                .rank(16)
                .oversample(8)
                .workers(4)
                .seed(1)
                .work_dir(dir.join(format!("fmt_{label}")).to_string_lossy().into_owned())
                .shard_format(fmt)
                .backend(backend.clone())
                .run()
                .unwrap()
        });
        let shard0 = std::fs::metadata(res.u_shards.shard_path(0))
            .map(|m| m.len())
            .unwrap_or(0);
        println!("{:>8} {:>12.2?} {:>14}", label, t, shard0 * res.shards as u64);
    }
}
