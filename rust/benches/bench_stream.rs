//! One-pass streaming SVD vs. the multi-pass pipeline — the perf
//! trajectory of the `stream/` subsystem.
//!
//! For a fixed tall-and-fat dataset, measure (a) `StreamSvd` consuming the
//! rows in exactly one forward pass and (b) the seekable multi-pass
//! `Svd::over` at the same rank, and report the σ gap the single pass
//! costs. Then sweep the batch size to chart absorb throughput (rows/s).
//! Prints the usual table and emits `BENCH_stream.json` so the trajectory
//! is machine-readable.

mod common;

use std::sync::Arc;
use tallfat::backend::native::NativeBackend;
use tallfat::stream::StreamSvd;
use tallfat::svd::Svd;

const K: usize = 16;

fn main() {
    let smoke = common::smoke();
    let (m, n) = if smoke { (1_500, 32) } else { (60_000, 48) };
    let batch_sweep: &[usize] = if smoke { &[64, 256] } else { &[256, 1024, 4096, 16384] };
    let reps = if smoke { 1 } else { 3 };

    let dir = common::bench_dir("stream");
    let spec = common::ensure_dataset(&dir, "stream", m, n, true);

    let stream_run = |batch_rows: usize, tag: &str| {
        StreamSvd::open(&spec.path)
            .rank(K)
            .oversample(8)
            .seed(7)
            .batch_rows(batch_rows)
            .work_dir(dir.join(format!("work_stream_{tag}")).to_string_lossy().into_owned())
            .run()
            .unwrap()
    };

    // Head-to-head at one batch size: wall time + the σ accuracy cost of
    // never revisiting a row.
    let head_batch = if smoke { 256 } else { 4096 };
    let (streamed, t_stream) = common::time_best(reps, || stream_run(head_batch, "head"));
    let (batch, t_batch) = common::time_best(reps, || {
        Svd::over(&spec)
            .unwrap()
            .rank(K)
            .oversample(8)
            .seed(7)
            .workers(4)
            .block(256)
            .work_dir(dir.join("work_batch").to_string_lossy().into_owned())
            .backend(Arc::new(NativeBackend::new()))
            .run()
            .unwrap()
    });
    let shared = streamed.k.min(batch.k);
    assert!(shared > 0, "both paths must recover a nonzero rank");
    let sigma_rel_max = (0..shared)
        .map(|i| (streamed.sigma[i] - batch.sigma[i]).abs() / batch.sigma[i].abs().max(1e-300))
        .fold(0.0f64, f64::max);

    common::header(&format!(
        "one-pass stream vs multi-pass svd ({m}x{n}, k={K}, batch_rows={head_batch})"
    ));
    println!(
        "{:>12} {:>10} {:>12} {:>14}",
        "mode", "time(s)", "rows/s", "sigma_rel_max"
    );
    println!(
        "{:>12} {:>10.3} {:>12.0} {:>14.3e}",
        "one_pass",
        t_stream.as_secs_f64(),
        common::rate(m as u64, t_stream),
        sigma_rel_max
    );
    println!(
        "{:>12} {:>10.3} {:>12.0} {:>14}",
        "multi_pass",
        t_batch.as_secs_f64(),
        common::rate(m as u64, t_batch),
        "-"
    );

    // Batch-size sweep: absorb throughput of the single forward pass.
    common::header("stream absorb throughput by batch size");
    println!("{:>12} {:>10} {:>12}", "batch_rows", "time(s)", "rows/s");
    let mut sweep = Vec::new();
    for &b in batch_sweep {
        let (_, t) = common::time_best(reps, || stream_run(b, &format!("b{b}")));
        let rps = common::rate(m as u64, t);
        println!("{:>12} {:>10.3} {:>12.0}", b, t.as_secs_f64(), rps);
        sweep.push(format!(
            "{{\"batch_rows\":{b},\"s\":{:.6},\"rows_per_s\":{rps:.1}}}",
            t.as_secs_f64()
        ));
    }

    let json = format!(
        "{{\"bench\":\"stream\",\"m\":{m},\"n\":{n},\"k\":{K},\
         \"one_pass_s\":{:.6},\"multi_pass_s\":{:.6},\"sigma_rel_max\":{sigma_rel_max:.6e},\
         \"sweep\":[{}]}}\n",
        t_stream.as_secs_f64(),
        t_batch.as_secs_f64(),
        sweep.join(",")
    );
    common::write_json("stream", &json);
}
