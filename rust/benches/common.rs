//! Shared helpers for the bench harnesses (criterion is unavailable
//! offline; each bench is a `harness = false` binary printing the table a
//! criterion run would, in the exact row format EXPERIMENTS.md records).

#![allow(dead_code)]

use std::path::PathBuf;
use std::time::{Duration, Instant};
use tallfat::io::dataset::{gen_streamed, Spectrum};
use tallfat::io::InputSpec;

/// Per-bench scratch directory (stable across runs so datasets cache).
pub fn bench_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("tallfat_bench").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Generate (or reuse) a streamed synthetic dataset.
pub fn ensure_dataset(dir: &PathBuf, stem: &str, m: usize, n: usize, bin: bool) -> InputSpec {
    let ext = if bin { "bin" } else { "csv" };
    let path = dir.join(format!("{stem}_{m}x{n}.{ext}")).to_string_lossy().into_owned();
    let spec = InputSpec::auto(path.clone());
    if !std::path::Path::new(&path).exists() {
        eprintln!("[gen] {path}");
        gen_streamed(
            &spec,
            m,
            n,
            16.min(n),
            Spectrum::Geometric { scale: 10.0, decay: 0.8 },
            0.01,
            2013,
        )
        .unwrap();
    }
    spec
}

/// Time one run of `f`.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Best-of-`reps` timing (steady-state, page-cache warm).
pub fn time_best<T>(reps: usize, mut f: impl FnMut() -> T) -> (T, Duration) {
    assert!(reps >= 1);
    let (mut out, mut best) = time_once(&mut f);
    for _ in 1..reps {
        let (o, d) = time_once(&mut f);
        if d < best {
            best = d;
            out = o;
        }
    }
    (out, best)
}

/// `items / duration` as a human rate.
pub fn rate(items: u64, d: Duration) -> f64 {
    items as f64 / d.as_secs_f64().max(1e-12)
}

pub fn header(title: &str) {
    println!("\n### {title}");
}

/// CI smoke mode: `TALLFAT_BENCH_SMOKE=1` shrinks datasets/reps so the
/// bench binaries (and their JSON emitters) can be exercised in seconds.
pub fn smoke() -> bool {
    match std::env::var("TALLFAT_BENCH_SMOKE") {
        Ok(v) => v != "0" && !v.is_empty(),
        Err(_) => false,
    }
}

/// Write a bench's machine-readable JSON next to the cargo cwd, so the
/// perf trajectory can be tracked run over run (the `bench_update`
/// convention: `BENCH_<name>.json`).
pub fn write_json(name: &str, json: &str) {
    let out = format!("BENCH_{name}.json");
    std::fs::write(&out, json).unwrap();
    println!("\nwrote {out}");
}
