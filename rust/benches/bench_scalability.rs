//! E1 — Split-Process scalability (the paper's Figure-3 story).
//!
//! The paper claims the Split-Process architecture scales by pointing each
//! of N workers at 1/N of the file. This box has one core, so we (a)
//! *measure* the single-worker streaming-ATA throughput, (b) verify the
//! chunk plan divides work evenly and in-process multi-worker runs give
//! identical results, and (c) feed the measured rate into the calibrated
//! cluster simulator to produce the multi-node speedup curve — including
//! the shared-file-server saturation knee the paper's deployment implies,
//! and the local-copies deployment it recommends for it.
//!
//! Output rows: workers, simulated stream/reduce/total seconds, speedup —
//! for both deployments.

mod common;

use tallfat::jobs::AtaRowJob;
use tallfat::simulator::{calibrate_rows_per_sec, simulate_split_process, ClusterParams};
use tallfat::splitproc;

fn main() {
    let dir = common::bench_dir("scalability");
    let (m, n) = (200_000, 64);
    let input = common::ensure_dataset(&dir, "ata", m, n, false);

    // ---- measure: single-worker streaming ATA -----------------------------
    common::header("E1.a measured single-worker streaming A^T A");
    let ((), warm) = common::time_once(|| {
        let r = splitproc::run(&input, 1, |_| Ok(AtaRowJob::new(n))).unwrap();
        assert_eq!(r.len(), 1);
    });
    let (rows, best) = common::time_best(3, || {
        let r = splitproc::run(&input, 1, |_| Ok(AtaRowJob::new(n))).unwrap();
        r[0].rows
    });
    let rate = calibrate_rows_per_sec(rows, best);
    println!("rows={rows}  n={n}  warm={warm:.2?}  best={best:.2?}  rate={rate:.0} rows/s");

    // ---- verify: multi-worker correctness + chunk balance ------------------
    common::header("E1.b in-process multi-worker equivalence (1 core)");
    let gram1 = {
        let r = splitproc::run(&input, 1, |_| Ok(AtaRowJob::new(n))).unwrap();
        splitproc::reduce_partials(r.into_iter().map(|w| w.job.into_partial()).collect()).unwrap()
    };
    println!("{:>8} {:>12} {:>14} {:>12}", "workers", "rows(min)", "rows(max)", "max|ΔG|");
    for w in [2usize, 4, 8, 16] {
        let r = splitproc::run(&input, w, |_| Ok(AtaRowJob::new(n))).unwrap();
        let rows: Vec<u64> = r.iter().map(|x| x.rows).collect();
        let gram =
            splitproc::reduce_partials(r.into_iter().map(|x| x.job.into_partial()).collect())
                .unwrap();
        println!(
            "{:>8} {:>12} {:>14} {:>12.2e}",
            w,
            rows.iter().min().unwrap(),
            rows.iter().max().unwrap(),
            gram.max_abs_diff(&gram1)
        );
    }

    // ---- simulate: the cluster curve ---------------------------------------
    // Job-intensity sweep: the shared-file-server knee sits where
    // N x per-worker byte demand crosses the link bandwidth, so the same
    // architecture is link-bound for cheap jobs (ATA n=64 streams ~245 MB/s
    // of CSV per worker) and CPU-bound for expensive ones (the fused SVD
    // pass measured ~40k rows/s in E6; the paper-literal virtual projection
    // ~3.5k rows/s in E3). All three simulated on the same file.
    common::header("E1.e shared file server: saturation knee vs per-row compute cost");
    println!(
        "{:>34} {:>12} {:>9} {:>9} {:>9} {:>9}",
        "job (measured rows/s)", "1 wrk(s)", "x2", "x4", "x8", "x16"
    );
    for (label, job_rate) in [
        (format!("ata n=64 ({rate:.0})"), rate),
        ("fused svd pass (40k)".to_string(), 40_000.0),
        ("virtual projection (3.5k)".to_string(), 3_500.0),
    ] {
        let p = ClusterParams { cpu_rows_per_sec: job_rate, ..ClusterParams::default() };
        let base = simulate_split_process(&p, &input, 1, (n * n * 8) as u64).unwrap().makespan;
        print!("{label:>34} {base:>12.3}");
        for w in [2usize, 4, 8, 16] {
            let r = simulate_split_process(&p, &input, w, (n * n * 8) as u64).unwrap();
            print!(" {:>8.2}x", base / r.makespan);
        }
        println!();
    }

    let partial_bytes = (n * n * 8) as u64;
    for (label, params) in [
        (
            "E1.c simulated cluster — shared file server (1 GbE)",
            ClusterParams { cpu_rows_per_sec: rate, ..ClusterParams::default() },
        ),
        (
            "E1.d simulated cluster — local file copies (paper §1's alternative)",
            ClusterParams { cpu_rows_per_sec: rate, local_copies: true, ..ClusterParams::default() },
        ),
    ] {
        common::header(label);
        println!(
            "{:>8} {:>12} {:>12} {:>12} {:>9} {:>11}",
            "workers", "stream(s)", "reduce(s)", "total(s)", "speedup", "efficiency"
        );
        let base = simulate_split_process(&params, &input, 1, partial_bytes).unwrap().makespan;
        for w in [1usize, 2, 4, 8, 16, 32] {
            let r = simulate_split_process(&params, &input, w, partial_bytes).unwrap();
            let speedup = base / r.makespan;
            println!(
                "{:>8} {:>12.4} {:>12.4} {:>12.4} {:>8.2}x {:>10.0}%",
                r.workers,
                r.stream_makespan,
                r.reduce_time,
                r.makespan,
                speedup,
                100.0 * speedup / w as f64
            );
        }
    }
}
