//! E1 — Split-Process scalability (the paper's Figure-3 story), plus the
//! dynamic-scheduler ablation.
//!
//! The paper claims the Split-Process architecture scales by pointing each
//! of N workers at 1/N of the file. This box has one core, so we (a)
//! *measure* the single-worker streaming-ATA throughput, (b) verify the
//! chunk plan divides work evenly and in-process multi-worker runs give
//! identical results, (c) feed the measured rate into the calibrated
//! cluster simulator to produce the multi-node speedup curve — including
//! the shared-file-server saturation knee the paper's deployment implies —
//! and (d) race the old static one-chunk-per-worker schedule against the
//! dynamic scheduler on a *skewed* workload where one quarter of the file
//! is 10x more expensive per row (the straggler scenario the static
//! schedule is worst at; sleep-based cost, so one core measures it fairly).
//!
//! Emits `BENCH_scalability.json` with the measured rate, the scheduler
//! ablation, and the simulated speedup curves. `TALLFAT_BENCH_SMOKE=1`
//! shrinks everything to CI-smoke size.

mod common;

use std::time::Duration;
use tallfat::io::InputSpec;
use tallfat::jobs::AtaRowJob;
use tallfat::simulator::{calibrate_rows_per_sec, simulate_split_process, ClusterParams};
use tallfat::splitproc::{self, SchedPolicy};

/// Stream a chunk, then sleep `rows x cost` where rows in the first
/// quarter of the file cost 10x — a deterministic straggler workload.
fn skewed_chunk_seconds(
    input: &InputSpec,
    workers: usize,
    policy: &SchedPolicy,
    file_len: u64,
    slow_us: u64,
    fast_us: u64,
) -> (usize, f64) {
    let t0 = std::time::Instant::now();
    let (results, stats) = splitproc::run_scheduled(input, workers, policy, |chunk| {
        let mut job = AtaRowJob::new(8);
        let rows = splitproc::run_chunk(input, chunk, &mut job)?;
        let start = chunk.byte_range.map(|r| r.start).unwrap_or(0);
        let per_row = if start < file_len / 4 { slow_us } else { fast_us };
        std::thread::sleep(Duration::from_micros(rows * per_row));
        Ok(rows)
    })
    .unwrap();
    let rows: u64 = results.iter().sum();
    assert!(rows > 0);
    (stats.chunks, t0.elapsed().as_secs_f64())
}

fn main() {
    let smoke = common::smoke();
    let dir = common::bench_dir("scalability");
    let (m, n) = if smoke { (5_000, 16) } else { (200_000, 64) };
    let input = common::ensure_dataset(&dir, "ata", m, n, false);

    // ---- measure: single-worker streaming ATA -----------------------------
    common::header("E1.a measured single-worker streaming A^T A");
    let ((), warm) = common::time_once(|| {
        let r = splitproc::run(&input, 1, |_| Ok(AtaRowJob::new(n))).unwrap();
        assert_eq!(r.len(), 1);
    });
    let reps = if smoke { 1 } else { 3 };
    let (rows, best) = common::time_best(reps, || {
        let r = splitproc::run(&input, 1, |_| Ok(AtaRowJob::new(n))).unwrap();
        r[0].rows
    });
    let rate = calibrate_rows_per_sec(rows, best);
    println!("rows={rows}  n={n}  warm={warm:.2?}  best={best:.2?}  rate={rate:.0} rows/s");

    // ---- verify: multi-worker correctness + chunk balance ------------------
    common::header("E1.b in-process multi-worker equivalence (1 core)");
    let gram1 = {
        let r = splitproc::run(&input, 1, |_| Ok(AtaRowJob::new(n))).unwrap();
        splitproc::reduce_partials(r.into_iter().map(|w| w.job.into_partial()).collect()).unwrap()
    };
    println!("{:>8} {:>12} {:>14} {:>12}", "workers", "rows(min)", "rows(max)", "max|ΔG|");
    for w in [2usize, 4, 8, 16] {
        let r = splitproc::run(&input, w, |_| Ok(AtaRowJob::new(n))).unwrap();
        let rows: Vec<u64> = r.iter().map(|x| x.rows).collect();
        let gram =
            splitproc::reduce_partials(r.into_iter().map(|x| x.job.into_partial()).collect())
                .unwrap();
        println!(
            "{:>8} {:>12} {:>14} {:>12.2e}",
            w,
            rows.iter().min().unwrap(),
            rows.iter().max().unwrap(),
            gram.max_abs_diff(&gram1)
        );
    }

    // ---- scheduler ablation: static vs dynamic under chunk skew -----------
    common::header("E1.c static one-chunk-per-worker vs dynamic scheduling (skewed chunks)");
    let skew_m = if smoke { 800 } else { 8_000 };
    let skew_input = common::ensure_dataset(&dir, "skew", skew_m, 8, false);
    let file_len = std::fs::metadata(&skew_input.path).unwrap().len();
    let workers = 4;
    let (slow_us, fast_us) = (200, 20);
    let (chunks_static, static_s) = skewed_chunk_seconds(
        &skew_input,
        workers,
        &SchedPolicy::static_one_per_worker(),
        file_len,
        slow_us,
        fast_us,
    );
    let dynamic_policy = SchedPolicy { chunks_per_worker: 8, ..SchedPolicy::default() };
    let (chunks_dynamic, dynamic_s) =
        skewed_chunk_seconds(&skew_input, workers, &dynamic_policy, file_len, slow_us, fast_us);
    let sched_speedup = static_s / dynamic_s.max(1e-9);
    println!(
        "{:>10} {:>8} {:>12}\n{:>10} {:>8} {:>12.4}\n{:>10} {:>8} {:>12.4}",
        "schedule", "chunks", "wall(s)", "static", chunks_static, static_s, "dynamic",
        chunks_dynamic, dynamic_s
    );
    println!("dynamic speedup on the straggler scenario: {sched_speedup:.2}x");

    // ---- simulate: the cluster curve ---------------------------------------
    // Job-intensity sweep: the shared-file-server knee sits where
    // N x per-worker byte demand crosses the link bandwidth, so the same
    // architecture is link-bound for cheap jobs (ATA n=64 streams ~245 MB/s
    // of CSV per worker) and CPU-bound for expensive ones (the fused SVD
    // pass measured ~40k rows/s in E6; the paper-literal virtual projection
    // ~3.5k rows/s in E3). All three simulated on the same file.
    common::header("E1.f shared file server: saturation knee vs per-row compute cost");
    println!(
        "{:>34} {:>12} {:>9} {:>9} {:>9} {:>9}",
        "job (measured rows/s)", "1 wrk(s)", "x2", "x4", "x8", "x16"
    );
    for (label, job_rate) in [
        (format!("ata n={n} ({rate:.0})"), rate),
        ("fused svd pass (40k)".to_string(), 40_000.0),
        ("virtual projection (3.5k)".to_string(), 3_500.0),
    ] {
        let p = ClusterParams { cpu_rows_per_sec: job_rate, ..ClusterParams::default() };
        let base = simulate_split_process(&p, &input, 1, (n * n * 8) as u64).unwrap().makespan;
        print!("{label:>34} {base:>12.3}");
        for w in [2usize, 4, 8, 16] {
            let r = simulate_split_process(&p, &input, w, (n * n * 8) as u64).unwrap();
            print!(" {:>8.2}x", base / r.makespan);
        }
        println!();
    }

    let partial_bytes = (n * n * 8) as u64;
    let mut sim_points = Vec::new();
    for (key, label, params) in [
        (
            "shared_fs",
            "E1.d simulated cluster — shared file server (1 GbE)",
            ClusterParams { cpu_rows_per_sec: rate, ..ClusterParams::default() },
        ),
        (
            "local_copies",
            "E1.e simulated cluster — local file copies (paper §1's alternative)",
            ClusterParams {
                cpu_rows_per_sec: rate,
                local_copies: true,
                ..ClusterParams::default()
            },
        ),
    ] {
        common::header(label);
        println!(
            "{:>8} {:>12} {:>12} {:>12} {:>9} {:>11}",
            "workers", "stream(s)", "reduce(s)", "total(s)", "speedup", "efficiency"
        );
        let base = simulate_split_process(&params, &input, 1, partial_bytes).unwrap().makespan;
        for w in [1usize, 2, 4, 8, 16, 32] {
            let r = simulate_split_process(&params, &input, w, partial_bytes).unwrap();
            let speedup = base / r.makespan;
            println!(
                "{:>8} {:>12.4} {:>12.4} {:>12.4} {:>8.2}x {:>10.0}%",
                r.workers,
                r.stream_makespan,
                r.reduce_time,
                r.makespan,
                speedup,
                100.0 * speedup / w as f64
            );
            sim_points.push(format!(
                "{{\"deployment\":\"{key}\",\"workers\":{w},\"total_s\":{:.6},\"speedup\":{speedup:.4}}}",
                r.makespan
            ));
        }
    }

    let json = format!(
        concat!(
            "{{\"bench\":\"scalability\",\"smoke\":{},\"m\":{},\"n\":{},",
            "\"measured_rows_per_s\":{:.1},",
            "\"sched_skew\":{{\"workers\":{},\"skew_rows\":{},",
            "\"chunks_static\":{},\"chunks_dynamic\":{},",
            "\"static_s\":{:.6},\"dynamic_s\":{:.6},\"speedup\":{:.4}}},",
            "\"sim\":[{}]}}\n"
        ),
        common::smoke(),
        m,
        n,
        rate,
        workers,
        skew_m,
        chunks_static,
        chunks_dynamic,
        static_s,
        dynamic_s,
        sched_speedup,
        sim_points.join(",")
    );
    common::write_json("scalability", &json);
}
