//! Sparse input path vs densify-then-dense (the PR-5 workload class).
//!
//! Two comparisons on a ≥90%-sparse tall matrix:
//!
//! 1. **Kernel**: fused `sp_matmul_gram` over CSR blocks vs densifying the
//!    same blocks and running the dense `matmul_gram` hot path.
//! 2. **End-to-end**: `Svd::over(csr input)` vs the same factorization of
//!    the densified copy (`.bin`) — same rank, seed, workers.
//!
//! Emits `BENCH_sparse.json`. `TALLFAT_BENCH_SMOKE=1` shrinks everything
//! so CI can exercise the emitter in seconds.

mod common;

use tallfat::io::dataset::gen_sparse_streamed;
use tallfat::io::InputSpec;
use tallfat::linalg::{matmul_gram, sp_matmul_gram, Matrix, SparseMatrix};
use tallfat::rng::Gaussian;
use tallfat::svd::Svd;

fn main() {
    let smoke = common::smoke();
    let (m, n, density) = if smoke { (3_000, 64, 0.05) } else { (40_000, 256, 0.05) };
    let k = if smoke { 8 } else { 16 };
    let reps = if smoke { 1 } else { 2 };
    let dir = common::bench_dir("sparse");

    // ---- dataset: one sparse source, one densified copy ------------------
    let csr = InputSpec::csr(
        dir.join(format!("a_{m}x{n}.csr")).to_string_lossy().into_owned(),
    );
    if !std::path::Path::new(&csr.path).exists() {
        eprintln!("[gen] {}", csr.path);
        gen_sparse_streamed(&csr, m, n, density, 2013).unwrap();
    }
    let sparse = tallfat::io::read_sparse(&csr).unwrap();
    let nnz = sparse.nnz();
    let dense_copy = sparse.to_dense();
    let bin = InputSpec::bin(
        dir.join(format!("a_{m}x{n}.bin")).to_string_lossy().into_owned(),
    );
    if !std::path::Path::new(&bin.path).exists() {
        tallfat::io::write_matrix(&dense_copy, &bin).unwrap();
    }
    common::header(&format!(
        "sparse vs densify — {m}x{n}, nnz={nnz} ({:.1}% fill)",
        100.0 * sparse.density()
    ));

    // ---- kernel-level: fused project+gram --------------------------------
    let g = Gaussian::new(7);
    let kp = k + 8;
    let omega = Matrix::from_fn(n, kp, |i, j| g.sample(1_000_000 + i as u64, j as u64));
    let block_rows = 4096.min(m);
    let sparse_block = {
        let mut b = SparseMatrix::with_cols(n);
        for i in 0..block_rows {
            let (idx, val) = sparse.row(i);
            b.push_row(idx, val).unwrap();
        }
        b
    };
    let (y_sp, t_kernel_sparse) =
        common::time_best(reps, || sp_matmul_gram(&sparse_block, &omega).unwrap());
    let (y_dn, t_kernel_densify) = common::time_best(reps, || {
        let dense_block = sparse_block.to_dense();
        matmul_gram(&dense_block, &omega).unwrap()
    });
    let kernel_diff = y_sp.0.max_abs_diff(&y_dn.0);
    println!(
        "{:<34} {:>12} {:>14}",
        "kernel (project+gram, 1 block)", "time", "max|ΔY|"
    );
    println!("{:<34} {:>12.2?} {:>14}", "csr sp_matmul_gram", t_kernel_sparse, "-");
    println!(
        "{:<34} {:>12.2?} {:>14.1e}",
        "densify + matmul_gram", t_kernel_densify, kernel_diff
    );

    // ---- end-to-end factorization ----------------------------------------
    let run = |input: &InputSpec, sub: &str| {
        let work = dir.join(format!("work_{sub}"));
        let _ = std::fs::remove_dir_all(&work);
        Svd::over(input)
            .unwrap()
            .rank(k)
            .oversample(8)
            .workers(4)
            .block(256)
            .seed(5)
            .work_dir(work.to_string_lossy().into_owned())
            .run()
            .unwrap()
    };
    let (r_sparse, t_svd_sparse) = common::time_once(|| run(&csr, "sparse"));
    let (r_dense, t_svd_dense) = common::time_once(|| run(&bin, "dense"));
    let mut sigma_rel = 0.0f64;
    for i in 0..k {
        sigma_rel =
            sigma_rel.max((r_sparse.sigma[i] - r_dense.sigma[i]).abs() / r_dense.sigma[0]);
    }
    let speedup = t_svd_dense.as_secs_f64() / t_svd_sparse.as_secs_f64().max(1e-9);
    println!(
        "\n{:<34} {:>12} {:>10}",
        "end-to-end svd (k, same seed)", "time", "rows/s"
    );
    println!(
        "{:<34} {:>12.2?} {:>10.0}",
        "csr input (sparse kernels)",
        t_svd_sparse,
        common::rate(m as u64, t_svd_sparse)
    );
    println!(
        "{:<34} {:>12.2?} {:>10.0}",
        "bin input (dense kernels)",
        t_svd_dense,
        common::rate(m as u64, t_svd_dense)
    );
    println!("speedup {speedup:.2}x, max sigma drift {sigma_rel:.1e}");

    let json = format!(
        "{{\"bench\":\"sparse\",\"m\":{m},\"n\":{n},\"k\":{k},\"nnz\":{nnz},\
         \"density\":{:.6},\"kernel_sparse_s\":{:.6},\"kernel_densify_s\":{:.6},\
         \"svd_sparse_s\":{:.6},\"svd_dense_s\":{:.6},\"speedup\":{:.4},\
         \"sigma_rel_drift\":{:.3e},\"smoke\":{smoke}}}\n",
        sparse.density(),
        t_kernel_sparse.as_secs_f64(),
        t_kernel_densify.as_secs_f64(),
        t_svd_sparse.as_secs_f64(),
        t_svd_dense.as_secs_f64(),
        speedup,
        sigma_rel,
    );
    common::write_json("sparse", &json);
}
