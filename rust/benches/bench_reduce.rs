//! E9 — reduction topology: star (every partial ships to the leader) vs
//! tree (held leaves, relayed pairwise merges, banded TSQR W folds).
//!
//! One box, in-process TCP workers — so the *wall-time* columns mostly
//! show protocol/scheduling overhead, not network wins; the headline
//! number is `leader_peak_bytes`: the leader's tracked reduce-state
//! high-water mark, which is `O(chunks · n·k')` for star and
//! `O(k'^2 log w)` for tree regardless of where the workers live.
//!
//! Emits `BENCH_reduce.json` with one point per (workers, mode).
//! `TALLFAT_BENCH_SMOKE=1` shrinks everything to CI-smoke size.

mod common;

use std::sync::Arc;
use tallfat::backend::native::NativeBackend;
use tallfat::cluster::{worker, ClusterExecutor};
use tallfat::io::InputSpec;
use tallfat::svd::{ReduceMode, Svd};

fn free_addr() -> String {
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap().to_string();
    drop(probe);
    addr
}

fn spawn_workers(addr: &str, n: usize) -> Vec<std::thread::JoinHandle<()>> {
    (0..n)
        .map(|_| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let stream = loop {
                    match std::net::TcpStream::connect(&addr) {
                        Ok(s) => break s,
                        Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                    }
                };
                worker::serve(stream, Arc::new(NativeBackend::new())).unwrap();
            })
        })
        .collect()
}

/// One full distributed factorization; returns (wall seconds, leader peak
/// reduce-state bytes).
fn run_once(
    input: &InputSpec,
    dir: &std::path::Path,
    k: usize,
    workers: usize,
    mode: ReduceMode,
) -> (f64, u64) {
    let addr = free_addr();
    let handles = spawn_workers(&addr, workers);
    let mut cluster = ClusterExecutor::accept(&addr, workers).unwrap();
    let work = dir.join(format!("{}_{}w", mode.name(), workers)).to_string_lossy().into_owned();
    let (result, wall) = common::time_once(|| {
        Svd::over(input)
            .unwrap()
            .rank(k)
            .oversample(8)
            .workers(workers)
            .seed(2013)
            .work_dir(work.clone())
            .reduce(mode)
            .executor(&mut cluster)
            .run()
            .unwrap()
    });
    assert_eq!(result.k, k);
    let peak = cluster.mem_peak();
    cluster.shutdown().unwrap();
    for h in handles {
        h.join().unwrap();
    }
    (wall.as_secs_f64(), peak)
}

fn main() {
    let smoke = common::smoke();
    let dir = common::bench_dir("reduce");
    let (m, n, k) = if smoke { (2_000, 48, 6) } else { (30_000, 192, 16) };
    let input = common::ensure_dataset(&dir, "reduce", m, n, true);
    let fleet: &[usize] = if smoke { &[2] } else { &[2, 4, 8] };

    common::header("E9 star vs tree reduction (distributed, in-process workers)");
    println!(
        "{:>8} {:>6} {:>10} {:>18} {:>9}",
        "workers", "mode", "wall(s)", "leader_peak(B)", "peak x"
    );
    let mut points = Vec::new();
    for &w in fleet {
        let mut star_peak = 0u64;
        for mode in [ReduceMode::Star, ReduceMode::Tree] {
            let (wall, peak) = run_once(&input, &dir, k, w, mode);
            let ratio = if mode == ReduceMode::Star {
                star_peak = peak.max(1);
                1.0
            } else {
                star_peak as f64 / peak.max(1) as f64
            };
            println!("{:>8} {:>6} {:>10.3} {:>18} {:>8.1}x", w, mode.name(), wall, peak, ratio);
            points.push(format!(
                "{{\"workers\":{w},\"mode\":\"{}\",\"wall_s\":{wall:.6},\
                 \"leader_peak_bytes\":{peak}}}",
                mode.name()
            ));
        }
    }

    let json = format!(
        "{{\"bench\":\"reduce\",\"smoke\":{},\"m\":{},\"n\":{},\"k\":{},\"points\":[{}]}}\n",
        smoke,
        m,
        n,
        k,
        points.join(",")
    );
    common::write_json("reduce", &json);
}
