//! E6 — end-to-end randomized rank-k SVD of a tall-and-fat matrix.
//!
//! DESIGN.md's headline workload scaled to this box: m=20,000, n=2048,
//! k=24 (+8 oversample = 32 sketch columns, matching the
//! `fused_b256_n2048_k32` artifact). Runs the full pipeline on the native
//! and XLA backends and prints the phase breakdown, throughput, and
//! accuracy. The paper's claim being reproduced: the whole factorization is
//! streaming passes over A plus leader math on 32x32 matrices only.
//!
//! Emits `BENCH_e2e.json` (per-backend wall time, throughput, accuracy) so
//! the end-to-end perf trajectory is machine-readable.
//! `TALLFAT_BENCH_SMOKE=1` shrinks the workload to CI-smoke size.

mod common;

use std::sync::Arc;
use tallfat::backend::{native::NativeBackend, xla::XlaBackend, BackendRef};
use tallfat::svd::{validate, Svd};

fn main() {
    let smoke = common::smoke();
    let dir = common::bench_dir("e2e");
    let (m, n, k) = if smoke { (2_000, 128, 8) } else { (20_000, 2048, 24) };
    let input = common::ensure_dataset(&dir, "e2e", m, n, true);
    let bytes = std::fs::metadata(&input.path).unwrap().len();

    let mut backends: Vec<(&str, BackendRef)> = vec![("native", Arc::new(NativeBackend::new()))];
    match XlaBackend::start("artifacts", true) {
        Ok(x) => backends.push(("xla(auto)", Arc::new(x))),
        Err(e) => eprintln!("[warn] xla backend unavailable: {e} (run `make artifacts`)"),
    }

    let mut points = Vec::new();
    for (name, backend) in backends {
        common::header(&format!("E6 {m}x{n} k={k} — backend {name}"));
        let (result, elapsed) = common::time_once(|| {
            Svd::over(&input)
                .unwrap()
                .rank(k)
                .oversample(8)
                .workers(4)
                .block(256)
                .seed(1)
                .work_dir(dir.join(format!("work_{name}")).to_string_lossy().into_owned())
                .backend(backend.clone())
                .run()
                .unwrap()
        });
        println!("{}", result.report.render());
        let rows_per_s = 2.0 * m as f64 / elapsed.as_secs_f64();
        let mb_per_s = 2.0 * bytes as f64 / 1e6 / elapsed.as_secs_f64();
        println!(
            "end-to-end {elapsed:.2?}  |  {rows_per_s:.0} rows/s/pass  |  {mb_per_s:.0} MB/s of input"
        );
        let err = validate::reconstruction_error_streaming(&input, &result).unwrap();
        let ortho =
            validate::u_orthonormality_residual(&result.u_shards, result.shards, result.k).unwrap();
        println!("reconstruction error {err:.6}  |  U orthonormality {ortho:.2e}");
        println!(
            "sigma[0..6] = [{}]",
            result.sigma.iter().take(6).map(|s| format!("{s:.3}")).collect::<Vec<_>>().join(", ")
        );
        points.push(format!(
            concat!(
                "{{\"backend\":\"{}\",\"wall_s\":{:.6},\"rows_per_s_per_pass\":{:.1},",
                "\"input_mb_per_s\":{:.2},\"reconstruction_err\":{:.8},",
                "\"u_orthonormality\":{:.3e},\"shards\":{}}}"
            ),
            name,
            elapsed.as_secs_f64(),
            rows_per_s,
            mb_per_s,
            err,
            ortho,
            result.shards
        ));
    }

    let json = format!(
        "{{\"bench\":\"e2e\",\"smoke\":{smoke},\"m\":{m},\"n\":{n},\"k\":{k},\"backends\":[{}]}}\n",
        points.join(",")
    );
    common::write_json("e2e", &json);
}
