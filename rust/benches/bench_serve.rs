//! S1 — serve-path load generation: QPS and latency of the HTTP query
//! engine under concurrent clients, across micro-batch windows.
//!
//! Builds (or reuses) a rank-16 model of a 20,000 x 256 synthetic matrix,
//! boots the `ModelServer` on an ephemeral port, and hammers it with
//! concurrent connections issuing a project/similar mix. The batching
//! claim being measured: a wider coalescing window trades a little latency
//! for fewer, larger backend matmuls on the similarity scan.

mod common;

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use tallfat::backend::native::NativeBackend;
use tallfat::rng::Gaussian;
use tallfat::serve::{BatchOptions, Json, ModelServer, ModelStore, QueryEngine, ServeOptions};
use tallfat::svd::Svd;

const M: usize = 20_000;
const N: usize = 256;
const K: usize = 16;
const CLIENTS: usize = 8;
const REQS_PER_CLIENT: usize = 40;

fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted_ms.len() - 1) as f64).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

fn post_query(addr: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    let req = format!(
        "POST /query HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    resp
}

fn ensure_model(dir: &std::path::Path) -> std::path::PathBuf {
    let model_dir = dir.join(format!("model_{M}x{N}_k{K}"));
    if tallfat::serve::resolve_current(&model_dir).is_ok() {
        eprintln!("[reuse] {}", model_dir.display());
        return model_dir;
    }
    let input = common::ensure_dataset(&dir.to_path_buf(), "serve", M, N, true);
    eprintln!("[build] factorizing {M}x{N} k={K}...");
    let _ = Svd::over(&input)
        .unwrap()
        .rank(K)
        .oversample(8)
        .workers(4)
        .block(256)
        .seed(1)
        .work_dir(dir.join("svd_work").to_string_lossy().into_owned())
        .save_model(model_dir.to_string_lossy().into_owned())
        .run()
        .unwrap();
    model_dir
}

fn main() {
    let dir = common::bench_dir("serve");
    let model_dir = ensure_model(&dir);
    let gauss = Gaussian::new(99);

    common::header(&format!(
        "S1 serve load — {M}x{N} k={K} model, {CLIENTS} clients x {REQS_PER_CLIENT} reqs (project/similar mix)"
    ));
    println!(
        "{:>12} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "window(ms)", "wall(s)", "qps", "p50(ms)", "p95(ms)", "p99(ms)"
    );

    for window_ms in [0u64, 1, 2, 5] {
        let store = Arc::new(ModelStore::open(&model_dir, 8).unwrap());
        let engine =
            Arc::new(QueryEngine::new(store, Arc::new(NativeBackend::new())).unwrap());
        let total = (CLIENTS * REQS_PER_CLIENT) as u64;
        let server = ModelServer::bind(
            Arc::new(tallfat::serve::EngineHandle::fixed(engine)),
            &ServeOptions {
                addr: "127.0.0.1:0".into(),
                batch: BatchOptions {
                    window: std::time::Duration::from_millis(window_ms),
                    max_batch: 64,
                },
                max_requests: Some(total),
                ..ServeOptions::default()
            },
        )
        .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let srv = std::thread::spawn(move || server.run().unwrap());

        let t0 = std::time::Instant::now();
        let mut latencies: Vec<f64> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..CLIENTS)
                .map(|c| {
                    let addr = addr.clone();
                    let gauss = gauss;
                    scope.spawn(move || {
                        let mut lat = Vec::with_capacity(REQS_PER_CLIENT);
                        let mut row = vec![0.0f64; N];
                        for r in 0..REQS_PER_CLIENT {
                            let id = (c * REQS_PER_CLIENT + r) as u64;
                            gauss.fill_block(&mut row, id, 1, N, 1.0);
                            let row_json = Json::from_f64s(&row).render();
                            let body = if r % 2 == 0 {
                                format!("{{\"op\":\"similar\",\"row\":{row_json},\"k\":10}}\n")
                            } else {
                                format!("{{\"op\":\"project\",\"row\":{row_json}}}\n")
                            };
                            let t = std::time::Instant::now();
                            let resp = post_query(&addr, &body);
                            lat.push(t.elapsed().as_secs_f64() * 1e3);
                            assert!(resp.contains("200 OK"), "{resp}");
                        }
                        lat
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let wall = t0.elapsed();
        srv.join().unwrap();
        latencies.sort_by(f64::total_cmp);
        println!(
            "{:>12} {:>10.2} {:>10.0} {:>10.2} {:>10.2} {:>10.2}",
            window_ms,
            wall.as_secs_f64(),
            common::rate(total, wall),
            percentile(&latencies, 50.0),
            percentile(&latencies, 95.0),
            percentile(&latencies, 99.0),
        );
    }
    println!(
        "\npaper tie-in: U stays sharded on disk (LRU-cached), so the scan cost is\n\
         amortized across every similarity query coalesced into one batch."
    );
}
