//! S1 — serve-plane saturation: open-loop qps ramp against the shared
//! connection runtime, locating the knee and proving graceful degradation.
//!
//! Builds (or reuses) a rank-16 model of a 20,000 x 256 synthetic matrix,
//! boots the `ModelServer` on an ephemeral port, then offers load in an
//! *open loop*: each stage schedules requests at a fixed qps regardless of
//! completions, and latency is measured from the scheduled send time, so
//! queueing delay is charged to the server (no coordinated omission).
//! Clients hold keep-alive connections and read Content-Length-framed
//! replies. The claims being measured:
//!
//! * below the knee, p50/p99 stay flat while achieved qps tracks offered;
//! * past the knee, the server degrades *gracefully* — overload surfaces
//!   as explicit `503` + `Retry-After` JSON sheds, never as connection
//!   resets or stuck sockets (asserted per request);
//! * under forced overload (`max_inflight=1`, `max_queue=1`) every failed
//!   request is a well-formed shed and `/metrics` accounts for each one
//!   in `tallfat_net_shed_total`.
//!
//! `TALLFAT_BENCH_SMOKE=1` shrinks the model and the ramp so CI can
//! exercise the whole path (including `BENCH_serve.json`) in seconds.

mod common;

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};
use tallfat::backend::native::NativeBackend;
use tallfat::net::NetOptions;
use tallfat::rng::Gaussian;
use tallfat::serve::{
    BatchOptions, EngineHandle, Json, ModelServer, ModelStore, QueryEngine, ServeOptions,
};
use tallfat::svd::Svd;

const CLIENTS: usize = 8;

fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted_ms.len() - 1) as f64).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

/// One parsed HTTP reply off a keep-alive connection.
struct Reply {
    status: u16,
    retry_after: bool,
    body: String,
}

/// What one offered request turned into.
enum Outcome {
    Reply(Reply),
    /// Reset, refused, or torn mid-reply — exactly what graceful
    /// degradation promises never happens.
    Transport(String),
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Write `req`, read one framed reply; returns the stream when the server
/// kept the connection open.
fn exchange(
    mut s: TcpStream,
    req: &[u8],
) -> std::result::Result<(Reply, Option<TcpStream>), String> {
    s.write_all(req).map_err(|e| format!("write: {e}"))?;
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        let mut chunk = [0u8; 8192];
        match s.read(&mut chunk) {
            Ok(0) => return Err("closed before reply head".into()),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) => return Err(format!("read head: {e}")),
        }
    };
    let head = std::str::from_utf8(&buf[..head_end]).map_err(|_| "non-UTF-8 head".to_string())?;
    let mut lines = head.lines();
    let status: u16 = lines
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| "bad status line".to_string())?;
    let mut content_length: Option<usize> = None;
    let mut retry_after = false;
    let mut close = false;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.parse().ok();
            } else if name.eq_ignore_ascii_case("retry-after") {
                retry_after = true;
            } else if name.eq_ignore_ascii_case("connection") && value.eq_ignore_ascii_case("close")
            {
                close = true;
            }
        }
    }
    let len = content_length.ok_or_else(|| "reply without Content-Length".to_string())?;
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < len {
        let mut chunk = [0u8; 8192];
        match s.read(&mut chunk) {
            Ok(0) => return Err("closed mid-body".into()),
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e) => return Err(format!("read body: {e}")),
        }
    }
    body.truncate(len);
    let body = String::from_utf8(body).map_err(|_| "non-UTF-8 body".to_string())?;
    Ok((Reply { status, retry_after, body }, (!close).then_some(s)))
}

/// A keep-alive client: reuses one connection, reconnects when the server
/// closed it between requests (retrying the send once — the server never
/// saw it).
struct HttpClient {
    addr: String,
    conn: Option<TcpStream>,
}

impl HttpClient {
    fn new(addr: &str) -> HttpClient {
        HttpClient { addr: addr.to_string(), conn: None }
    }

    fn connect(&self) -> std::result::Result<TcpStream, String> {
        let s = TcpStream::connect(&self.addr).map_err(|e| format!("connect: {e}"))?;
        s.set_nodelay(true).ok();
        Ok(s)
    }

    fn request(&mut self, req: &[u8]) -> Outcome {
        let reused = self.conn.is_some();
        let stream = match self.conn.take().map(Ok).unwrap_or_else(|| self.connect()) {
            Ok(s) => s,
            Err(e) => return Outcome::Transport(e),
        };
        match exchange(stream, req) {
            Ok((reply, keep)) => {
                self.conn = keep;
                Outcome::Reply(reply)
            }
            // A reused connection the server reaped between requests looks
            // like a failed write / empty read; one fresh retry is safe.
            Err(_) if reused => match self.connect() {
                Ok(s) => match exchange(s, req) {
                    Ok((reply, keep)) => {
                        self.conn = keep;
                        Outcome::Reply(reply)
                    }
                    Err(e) => Outcome::Transport(e),
                },
                Err(e) => Outcome::Transport(e),
            },
            Err(e) => Outcome::Transport(e),
        }
    }
}

fn post_query_wire(body: &str) -> Vec<u8> {
    format!("POST /query HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}", body.len())
        .into_bytes()
}

struct StageResult {
    offered_qps: f64,
    sent: u64,
    ok: u64,
    shed: u64,
    other: u64,
    transport: u64,
    achieved_qps: f64,
    p50_ms: f64,
    p99_ms: f64,
}

/// Offer `qps` for `dur` across `CLIENTS` keep-alive connections; latency
/// is measured from each request's *scheduled* time.
fn run_stage(addr: &str, qps: f64, dur: Duration, requests: &[Vec<u8>]) -> StageResult {
    let total = (qps * dur.as_secs_f64()).round().max(1.0) as usize;
    let epoch = Instant::now() + Duration::from_millis(50);
    let per_client: Vec<(Vec<f64>, u64, u64, u64, u64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                scope.spawn(move || {
                    let mut client = HttpClient::new(addr);
                    let mut lats = Vec::new();
                    let (mut ok, mut shed, mut other, mut transport) = (0u64, 0u64, 0u64, 0u64);
                    let mut j = c;
                    while j < total {
                        let sched = epoch + Duration::from_secs_f64(j as f64 / qps);
                        let now = Instant::now();
                        if sched > now {
                            std::thread::sleep(sched - now);
                        }
                        match client.request(&requests[j % requests.len()]) {
                            Outcome::Reply(r) => {
                                lats.push(sched.elapsed().as_secs_f64() * 1e3);
                                match r.status {
                                    200 => ok += 1,
                                    503 => {
                                        assert!(r.retry_after, "503 without Retry-After");
                                        shed += 1;
                                    }
                                    _ => other += 1,
                                }
                            }
                            Outcome::Transport(_) => transport += 1,
                        }
                        j += CLIENTS;
                    }
                    (lats, ok, shed, other, transport)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = epoch.elapsed();
    let mut lats: Vec<f64> = Vec::new();
    let (mut ok, mut shed, mut other, mut transport) = (0u64, 0u64, 0u64, 0u64);
    for (l, o, s, x, t) in per_client {
        lats.extend(l);
        ok += o;
        shed += s;
        other += x;
        transport += t;
    }
    lats.sort_by(f64::total_cmp);
    StageResult {
        offered_qps: qps,
        sent: total as u64,
        ok,
        shed,
        other,
        transport,
        achieved_qps: common::rate(ok + shed + other, wall),
        p50_ms: percentile(&lats, 50.0),
        p99_ms: percentile(&lats, 99.0),
    }
}

fn ensure_model(dir: &std::path::Path, m: usize, n: usize, k: usize) -> std::path::PathBuf {
    let model_dir = dir.join(format!("model_{m}x{n}_k{k}"));
    if tallfat::serve::resolve_current(&model_dir).is_ok() {
        eprintln!("[reuse] {}", model_dir.display());
        return model_dir;
    }
    let input = common::ensure_dataset(&dir.to_path_buf(), "serve", m, n, true);
    eprintln!("[build] factorizing {m}x{n} k={k}...");
    let _ = Svd::over(&input)
        .unwrap()
        .rank(k)
        .oversample(8)
        .workers(4)
        .block(256)
        .seed(1)
        .work_dir(dir.join("svd_work").to_string_lossy().into_owned())
        .save_model(model_dir.to_string_lossy().into_owned())
        .run()
        .unwrap();
    model_dir
}

fn bind_server(model_dir: &std::path::Path, opts: &ServeOptions) -> ModelServer {
    let store = Arc::new(ModelStore::open(model_dir, 8).unwrap());
    let engine = Arc::new(QueryEngine::new(store, Arc::new(NativeBackend::new())).unwrap());
    ModelServer::bind(Arc::new(EngineHandle::fixed(engine)), opts).unwrap()
}

/// Forced overload: one warm handler plus a one-deep queue, a batching
/// window that pins the handler, and a burst that must shed. Returns
/// (requests, ok, shed, shed_total from /metrics).
fn overload_stage(model_dir: &std::path::Path, requests: &[Vec<u8>]) -> (u64, u64, u64, f64) {
    let server = bind_server(
        model_dir,
        &ServeOptions {
            addr: "127.0.0.1:0".into(),
            // The window pins the single handler long enough that the
            // burst below cannot drain through a one-deep queue.
            batch: BatchOptions { window: Duration::from_millis(50), max_batch: 64 },
            net: NetOptions { max_inflight: 1, max_queue: 1, ..NetOptions::default() },
            ..ServeOptions::default()
        },
    );
    let addr = server.local_addr().unwrap().to_string();
    let handle = server.handle();
    let srv = std::thread::spawn(move || server.run().unwrap());

    const BURST_CLIENTS: usize = 16;
    const BURST_REQS: usize = 4;
    let per_client: Vec<(u64, u64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..BURST_CLIENTS)
            .map(|c| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let (mut ok, mut shed) = (0u64, 0u64);
                    for r in 0..BURST_REQS {
                        let mut client = HttpClient::new(&addr);
                        match client.request(&requests[(c + r) % requests.len()]) {
                            Outcome::Reply(reply) if reply.status == 200 => ok += 1,
                            Outcome::Reply(reply) => {
                                // Graceful degradation, per response: an
                                // explicit, parseable 503 shed.
                                assert_eq!(reply.status, 503, "unexpected status");
                                assert!(reply.retry_after, "503 without Retry-After");
                                let line = Json::parse(reply.body.trim())
                                    .expect("shed body must be valid JSON");
                                assert_eq!(
                                    line.get("error").and_then(Json::as_str),
                                    Some("overloaded"),
                                    "{line:?}"
                                );
                                assert!(
                                    line.get("retry_after_s").and_then(Json::as_f64).is_some(),
                                    "{line:?}"
                                );
                                shed += 1;
                            }
                            Outcome::Transport(e) => panic!("transport error under overload: {e}"),
                        }
                    }
                    (ok, shed)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let (mut ok, mut shed) = (0u64, 0u64);
    for (o, s) in per_client {
        ok += o;
        shed += s;
    }
    assert!(shed > 0, "burst of {} never shed", BURST_CLIENTS * BURST_REQS);

    // The registry publishes every event-loop pass, so by the time this
    // inline GET is answered the burst's sheds are on the board.
    let mut client = HttpClient::new(&addr);
    let metrics = match client
        .request(b"GET /metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n")
    {
        Outcome::Reply(r) => r.body,
        Outcome::Transport(e) => panic!("metrics scrape failed: {e}"),
    };
    let shed_total: f64 = metrics
        .lines()
        .filter(|l| l.starts_with("tallfat_net_shed_total{") && l.contains("plane=\"serve\""))
        .filter_map(|l| l.rsplit(' ').next().and_then(|v| v.parse::<f64>().ok()))
        .sum();
    assert!(shed_total > 0.0, "net_shed_total missing from /metrics:\n{metrics}");

    handle.shutdown();
    srv.join().unwrap();
    ((BURST_CLIENTS * BURST_REQS) as u64, ok, shed, shed_total)
}

fn main() {
    let smoke = common::smoke();
    let (m, n, k) = if smoke { (2_000, 64, 8) } else { (20_000, 256, 16) };
    let (ramp, stage_dur): (Vec<f64>, Duration) = if smoke {
        (vec![50.0, 200.0], Duration::from_millis(600))
    } else {
        (vec![100.0, 200.0, 400.0, 800.0, 1600.0, 3200.0], Duration::from_secs(3))
    };
    let dir = common::bench_dir("serve");
    let model_dir = ensure_model(&dir, m, n, k);

    // A project/similar mix over a handful of pre-rendered wire requests.
    let gauss = Gaussian::new(99);
    let mut row = vec![0.0f64; n];
    let requests: Vec<Vec<u8>> = (0..16)
        .map(|i| {
            gauss.fill_block(&mut row, i as u64, 1, n, 1.0);
            let row_json = Json::from_f64s(&row).render();
            let body = if i % 2 == 0 {
                format!("{{\"op\":\"similar\",\"row\":{row_json},\"k\":10}}\n")
            } else {
                format!("{{\"op\":\"project\",\"row\":{row_json}}}\n")
            };
            post_query_wire(&body)
        })
        .collect();

    common::header(&format!(
        "S1 serve saturation — {m}x{n} k={k} model, open-loop ramp, {CLIENTS} keep-alive conns"
    ));
    println!(
        "{:>12} {:>12} {:>8} {:>8} {:>8} {:>10} {:>10}",
        "offered", "achieved", "ok", "shed", "xport", "p50(ms)", "p99(ms)"
    );

    let server = bind_server(&model_dir, &ServeOptions {
        addr: "127.0.0.1:0".into(),
        batch: BatchOptions { window: Duration::from_millis(1), max_batch: 64 },
        ..ServeOptions::default()
    });
    let addr = server.local_addr().unwrap().to_string();
    let handle = server.handle();
    let srv = std::thread::spawn(move || server.run().unwrap());

    // Warm the pool and the model cache off the record.
    let mut warm = HttpClient::new(&addr);
    for req in requests.iter().take(8) {
        if let Outcome::Transport(e) = warm.request(req) {
            panic!("warmup failed: {e}");
        }
    }
    drop(warm);

    let mut stages: Vec<StageResult> = Vec::new();
    for &qps in &ramp {
        let st = run_stage(&addr, qps, stage_dur, &requests);
        println!(
            "{:>12.0} {:>12.0} {:>8} {:>8} {:>8} {:>10.2} {:>10.2}",
            st.offered_qps, st.achieved_qps, st.ok, st.shed, st.transport, st.p50_ms, st.p99_ms
        );
        // Graceful degradation along the whole ramp: overload may shed,
        // but must never reset connections or answer anything else.
        assert_eq!(st.transport, 0, "transport errors at {qps} qps");
        assert_eq!(st.other, 0, "non-200/503 responses at {qps} qps");
        stages.push(st);
    }
    handle.shutdown();
    srv.join().unwrap();

    // The knee: first stage that can no longer track offered load (or
    // whose p99 blows past 8x the cold stage's).
    let base_p99 = stages[0].p99_ms.max(0.1);
    let knee = stages
        .iter()
        .find(|s| s.achieved_qps < 0.9 * s.offered_qps || s.p99_ms > 8.0 * base_p99)
        .map(|s| s.offered_qps);
    match knee {
        Some(q) => println!("\nknee: ~{q:.0} qps offered"),
        None => println!("\nknee: not reached within the ramp"),
    }

    common::header("S1b forced overload — max_inflight=1, max_queue=1, 64-request burst");
    let (burst, ok, shed, shed_total) = overload_stage(&model_dir, &requests);
    println!(
        "{burst} requests -> {ok} served, {shed} shed (all well-formed 503 JSON); \
         tallfat_net_shed_total = {shed_total}"
    );

    let stage_rows: Vec<Json> = stages
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("offered_qps", Json::num(s.offered_qps)),
                ("achieved_qps", Json::num(s.achieved_qps)),
                ("sent", Json::num(s.sent as f64)),
                ("ok", Json::num(s.ok as f64)),
                ("shed", Json::num(s.shed as f64)),
                ("transport_errors", Json::num(s.transport as f64)),
                ("p50_ms", Json::num(s.p50_ms)),
                ("p99_ms", Json::num(s.p99_ms)),
            ])
        })
        .collect();
    let out = Json::obj(vec![
        ("bench", Json::str("serve_saturation")),
        ("smoke", Json::Bool(smoke)),
        (
            "model",
            Json::obj(vec![
                ("m", Json::num(m as f64)),
                ("n", Json::num(n as f64)),
                ("k", Json::num(k as f64)),
            ]),
        ),
        ("clients", Json::num(CLIENTS as f64)),
        ("stage_duration_s", Json::num(stage_dur.as_secs_f64())),
        ("stages", Json::arr(stage_rows)),
        ("knee_qps", knee.map(Json::num).unwrap_or(Json::Null)),
        (
            "overload",
            Json::obj(vec![
                ("requests", Json::num(burst as f64)),
                ("ok", Json::num(ok as f64)),
                ("shed", Json::num(shed as f64)),
                ("transport_errors", Json::num(0.0)),
                ("all_sheds_well_formed", Json::Bool(true)),
                ("metrics_shed_total", Json::num(shed_total)),
            ]),
        ),
    ]);
    common::write_json("serve", &out.render());

    println!(
        "\npaper tie-in: admission control keeps the serve plane inside its\n\
         provisioned concurrency — past the knee, load sheds explicitly\n\
         instead of queueing without bound, so p99 under overload stays\n\
         within the same order as at the knee."
    );
}
