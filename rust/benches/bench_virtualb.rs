//! E3 — Virtual random B (paper §2.1): O(1) memory, identical result.
//!
//! Three ways to apply the Gaussian sketch `Y = A Ω`:
//!
//! 1. **materialized** — Ω stored (the paper's `MultJob` with `bfile`),
//!    n·k·8 bytes resident per worker;
//! 2. **worker-materialized** — Ω regenerated once per worker from the
//!    counter-based [`VirtualMatrix`] spec, then blocked matmul (what the
//!    SVD pipeline does: virtual across the cluster, dense within a worker);
//! 3. **fully virtual** — every Ω row regenerated on demand per A-row (the
//!    paper's §2.1 code, `np.random.seed(0)` per row), O(k) resident.
//!
//! The paper's claim: all three give the *same* Y (determinism), with
//! memory/compute traded. Rows report resident Ω bytes, wall time, and
//! max |ΔY| vs mode 1.

mod common;

use std::sync::Arc;
use tallfat::backend::native::NativeBackend;
use tallfat::config::InputFormat;
use tallfat::io::writer::ShardSet;
use tallfat::jobs::{MultJob, RandomProjRowJob};
use tallfat::linalg::Matrix;
use tallfat::rng::VirtualMatrix;
use tallfat::splitproc::{self, Blocked};
use tallfat::util::humanize::fmt_bytes;

fn main() {
    let dir = common::bench_dir("virtualb");
    let m = 5_000;
    let k = 32;
    let workers = 4;
    let backend = Arc::new(NativeBackend::new());

    for n in [256usize, 1024, 4096] {
        let input = common::ensure_dataset(&dir, "vb", m, n, true);
        common::header(&format!("E3 n={n} k={k} (m={m})"));
        let vm = VirtualMatrix::projection(0, n, k);
        let omega = vm.materialize();

        // 1. materialized Ω through the blocked backend
        let sh1 = ShardSet::new(&dir, &format!("Y1_{n}"), InputFormat::Bin).unwrap();
        let (shards1, t1) = common::time_best(2, || {
            let r = splitproc::run(&input, workers, |c| {
                let job = MultJob::new(backend.clone(), omega.clone(), &sh1, c.index)?;
                Ok(Blocked::new(job, 256, n))
            })
            .unwrap();
            r.len()
        });

        // 2. worker-materialized from the virtual spec
        let sh2 = ShardSet::new(&dir, &format!("Y2_{n}"), InputFormat::Bin).unwrap();
        let (_, t2) = common::time_best(2, || {
            let r = splitproc::run(&input, workers, |c| {
                let w_omega = vm.materialize(); // per-worker regeneration
                let job = MultJob::new(backend.clone(), w_omega, &sh2, c.index)?;
                Ok(Blocked::new(job, 256, n))
            })
            .unwrap();
            r.len()
        });

        // 3. fully virtual, row-at-a-time (paper-literal)
        let sh3 = ShardSet::new(&dir, &format!("Y3_{n}"), InputFormat::Bin).unwrap();
        let (_, t3) = common::time_best(1, || {
            let r = splitproc::run(&input, workers, |c| {
                RandomProjRowJob::new(vm.clone(), &sh3, c.index)
            })
            .unwrap();
            r.len()
        });

        let y1: Matrix = sh1.merge_to_matrix(shards1).unwrap();
        let y2: Matrix = sh2.merge_to_matrix(shards1).unwrap();
        let y3: Matrix = sh3.merge_to_matrix(shards1).unwrap();

        println!(
            "{:<24} {:>14} {:>12} {:>14} {:>10}",
            "mode", "Ω resident", "time", "rows/s", "max|ΔY|"
        );
        for (name, bytes, t, dy) in [
            ("materialized", (n * k * 8) as u64, t1, 0.0),
            ("worker-materialized", (n * k * 8) as u64, t2, y2.max_abs_diff(&y1)),
            ("fully virtual (paper)", (k * 8) as u64, t3, y3.max_abs_diff(&y1)),
        ] {
            println!(
                "{:<24} {:>14} {:>12.2?} {:>14.0} {:>10.1e}",
                name,
                fmt_bytes(bytes),
                t,
                common::rate(m as u64, t),
                dy
            );
        }
        sh1.cleanup(shards1);
        sh2.cleanup(shards1);
        sh3.cleanup(shards1);
    }
    println!(
        "\nshape check: identical Y across all modes (determinism of the\n\
         counter-based Ω), memory O(nk) -> O(k), compute overhead grows with n."
    );
}
