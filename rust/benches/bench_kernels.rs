//! E7 — per-block kernel latency: native rust vs the AOT XLA artifacts.
//!
//! Measures each compiled program (gram / project / fused / tmul /
//! urecover / eigh) at its artifact shape against the pure-rust
//! implementation of the same block op, plus the result agreement. This is
//! the L1/L3 boundary cost: what one `Backend` call costs on the hot path.

mod common;

use tallfat::backend::{native::NativeBackend, xla::XlaBackend, Backend};
use tallfat::linalg::Matrix;
use tallfat::rng::Gaussian;

fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
    let g = Gaussian::new(seed);
    Matrix::from_fn(rows, cols, |i, j| g.sample(i as u64, j as u64))
}

fn row(op: &str, shape: &str, native_t: std::time::Duration, xla_t: Option<std::time::Duration>, diff: f64) {
    match xla_t {
        Some(x) => println!(
            "{:<16} {:<22} {:>12.1?} {:>12.1?} {:>8.2}x {:>11.1e}",
            op,
            shape,
            native_t,
            x,
            native_t.as_secs_f64() / x.as_secs_f64(),
            diff
        ),
        None => println!("{:<16} {:<22} {:>12.1?} {:>12} {:>8} {:>11}", op, shape, native_t, "-", "-", "-"),
    }
}

const REPS: usize = 20;

fn main() {
    let native = NativeBackend::new();
    let xla = match XlaBackend::start("artifacts", false) {
        Ok(x) => Some(x),
        Err(e) => {
            eprintln!("[warn] xla unavailable: {e}; native-only rows");
            None
        }
    };

    common::header("E7 per-block latency (best of 20), native f64 vs artifact f32");
    println!(
        "{:<16} {:<22} {:>12} {:>12} {:>8} {:>11}",
        "op", "shape", "native", "xla", "nat/xla", "max|Δ|"
    );

    // gram: b=256, n in {64, 256}
    for n in [64usize, 256] {
        let x = randm(256, n, 1);
        let (g_nat, t_nat) = common::time_best(REPS, || native.gram_block(&x).unwrap());
        let (diff, t_xla) = match &xla {
            Some(b) => {
                let (g_xla, t) = common::time_best(REPS, || b.gram_block(&x).unwrap());
                (g_xla.max_abs_diff(&g_nat), Some(t))
            }
            None => (0.0, None),
        };
        row("gram", &format!("256x{n}"), t_nat, t_xla, diff);
    }

    // project: b=256, (n, k) in {(256,32), (1024,32)}
    for n in [256usize, 1024] {
        let x = randm(256, n, 2);
        let w = randm(n, 32, 3);
        let (y_nat, t_nat) = common::time_best(REPS, || native.project_block(&x, &w).unwrap());
        let (diff, t_xla) = match &xla {
            Some(b) => {
                let (y_xla, t) = common::time_best(REPS, || b.project_block(&x, &w).unwrap());
                (y_xla.max_abs_diff(&y_nat), Some(t))
            }
            None => (0.0, None),
        };
        row("project", &format!("256x{n} · {n}x32"), t_nat, t_xla, diff);
    }

    // fused project+gram: the pass-1 hot path
    for n in [256usize, 1024, 2048] {
        let x = randm(256, n, 4);
        let w = randm(n, 32, 5);
        let ((y_nat, g_nat), t_nat) =
            common::time_best(REPS, || native.project_gram_block(&x, &w).unwrap());
        let (diff, t_xla) = match &xla {
            Some(b) => {
                let ((y, g), t) = common::time_best(REPS, || b.project_gram_block(&x, &w).unwrap());
                (y.max_abs_diff(&y_nat).max(g.max_abs_diff(&g_nat)), Some(t))
            }
            None => (0.0, None),
        };
        row("fused proj+gram", &format!("256x{n} · {n}x32"), t_nat, t_xla, diff);
    }

    // tmul: pass-2 accumulation
    for n in [256usize, 1024, 2048] {
        let x = randm(256, n, 6);
        let z = randm(256, 32, 7);
        let (w_nat, t_nat) = common::time_best(REPS, || native.tmul_block(&x, &z).unwrap());
        let (diff, t_xla) = match &xla {
            Some(b) => {
                let (w_xla, t) = common::time_best(REPS, || b.tmul_block(&x, &z).unwrap());
                (w_xla.max_abs_diff(&w_nat), Some(t))
            }
            None => (0.0, None),
        };
        row("tmul", &format!("{n}x256 · 256x32"), t_nat, t_xla, diff);
    }

    // urecover: U block rotation
    for k in [16usize, 32] {
        let y = randm(256, k, 8);
        let m = randm(k, k, 9);
        let (u_nat, t_nat) = common::time_best(REPS, || native.u_recover_block(&y, &m).unwrap());
        let (diff, t_xla) = match &xla {
            Some(b) => {
                let (u_xla, t) = common::time_best(REPS, || b.u_recover_block(&y, &m).unwrap());
                (u_xla.max_abs_diff(&u_nat), Some(t))
            }
            None => (0.0, None),
        };
        row("urecover", &format!("256x{k} · {k}x{k}"), t_nat, t_xla, diff);
    }

    // eigh: the leader's k'x k' solve (artifact = jacobi sweeps in HLO)
    for k in [16usize, 32, 64] {
        let base = randm(k, k, 10);
        let mut sym = Matrix::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                sym.set(i, j, 0.5 * (base.get(i, j) + base.get(j, i)));
            }
        }
        let ((ev_nat, _), t_nat) = common::time_best(REPS, || native.eigh(&sym).unwrap());
        let (diff, t_xla) = match &xla {
            Some(b) => {
                let ((ev_xla, _), t) = common::time_best(REPS, || b.eigh(&sym).unwrap());
                let d = ev_nat
                    .iter()
                    .zip(&ev_xla)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f64, f64::max);
                (d, Some(t))
            }
            None => (0.0, None),
        };
        row("eigh", &format!("{k}x{k}"), t_nat, t_xla, diff);
    }
}
