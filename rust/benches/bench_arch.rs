//! E2 — Split-Process vs Map-Reduce (paper Figure 2 vs Figure 3).
//!
//! Same `A^T A` job on both engines. Split-Process reduces in memory
//! (workers ship one n x n partial each); faithful MR materializes every
//! outer-product element as a shuffled (key, value) pair. We report wall
//! time, bytes materialized, and the simulated cluster makespans where the
//! shuffle crosses a real network.

mod common;

use tallfat::jobs::AtaRowJob;
use tallfat::mapreduce::{ata_mapreduce, AtaMrMode};
use tallfat::simulator::{
    calibrate_rows_per_sec, simulate_mapreduce, simulate_split_process, ClusterParams,
};
use tallfat::splitproc;
use tallfat::util::humanize::fmt_bytes;

fn main() {
    let dir = common::bench_dir("arch");
    let (m, n) = (20_000, 32);
    let input = common::ensure_dataset(&dir, "arch", m, n, false);
    let workers = 4;

    // ---- measured, in-process ----------------------------------------------
    common::header("E2.a measured (in-process, 4 workers/mappers)");
    let (gram_sp, t_sp) = common::time_best(3, || {
        let r = splitproc::run(&input, workers, |_| Ok(AtaRowJob::new(n))).unwrap();
        splitproc::reduce_partials(r.into_iter().map(|w| w.job.into_partial()).collect()).unwrap()
    });
    let sp_bytes = (workers * n * n * 8) as u64; // the partials are ALL it ships

    let ((gram_full, stats_full), t_full) = common::time_best(1, || {
        ata_mapreduce(&input, dir.join("mr_full"), workers, workers, AtaMrMode::Full).unwrap()
    });
    let ((gram_up, stats_up), t_up) = common::time_best(1, || {
        ata_mapreduce(&input, dir.join("mr_up"), workers, workers, AtaMrMode::Upper).unwrap()
    });

    println!(
        "{:<28} {:>10} {:>16} {:>12} {:>10}",
        "engine", "time", "materialized", "pairs", "max|ΔG|"
    );
    println!(
        "{:<28} {:>10.2?} {:>16} {:>12} {:>10}",
        "split-process", t_sp, fmt_bytes(sp_bytes), "-", "0"
    );
    println!(
        "{:<28} {:>10.2?} {:>16} {:>12} {:>10.1e}",
        "map-reduce (full)",
        t_full,
        fmt_bytes(stats_full.shuffle_bytes),
        stats_full.pairs_emitted,
        gram_full.max_abs_diff(&gram_sp)
    );
    println!(
        "{:<28} {:>10.2?} {:>16} {:>12} {:>10.1e}",
        "map-reduce (upper-tri)",
        t_up,
        fmt_bytes(stats_up.shuffle_bytes),
        stats_up.pairs_emitted,
        gram_up.max_abs_diff(&gram_sp)
    );
    println!(
        "\nshuffle amplification: MR materializes {:.0}x (full) / {:.0}x (upper) the bytes\nsplit-process ships; measured wall-time gap {:.1}x / {:.1}x.",
        stats_full.shuffle_bytes as f64 / sp_bytes as f64,
        stats_up.shuffle_bytes as f64 / sp_bytes as f64,
        t_full.as_secs_f64() / t_sp.as_secs_f64(),
        t_up.as_secs_f64() / t_sp.as_secs_f64()
    );

    // ---- simulated on a cluster --------------------------------------------
    common::header("E2.b simulated 1 GbE cluster (calibrated from E2.a)");
    let rate = calibrate_rows_per_sec(m as u64, t_sp); // ATA-rate incl. reduce
    let params = ClusterParams { cpu_rows_per_sec: rate, ..ClusterParams::default() };
    println!("{:>8} {:>16} {:>18} {:>18}", "workers", "split-process(s)", "MR full(s)", "MR upper(s)");
    for w in [2usize, 4, 8, 16] {
        let sp = simulate_split_process(&params, &input, w, (n * n * 8) as u64).unwrap();
        let mr_f =
            simulate_mapreduce(&params, &input, w, stats_full.shuffle_bytes, stats_full.pairs_emitted)
                .unwrap();
        let mr_u =
            simulate_mapreduce(&params, &input, w, stats_up.shuffle_bytes, stats_up.pairs_emitted)
                .unwrap();
        println!(
            "{:>8} {:>16.4} {:>18.4} {:>18.4}",
            w, sp.makespan, mr_f.makespan, mr_u.makespan
        );
    }
}
