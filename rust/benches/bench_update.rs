//! Incremental update vs. full refactorization — the perf trajectory of
//! the model lifecycle.
//!
//! For a fixed base model (m0 x n, rank k) and several new-row fractions f,
//! measure (a) `Update::of(model).rows(batch).run()` and (b) a from-scratch
//! `Svd::over(A0 ‖ batch)` with a model save (the honest alternative: both
//! paths end with a servable generation on disk). Prints the usual table
//! and emits `BENCH_update.json` so the trajectory is machine-readable.

mod common;

use std::sync::Arc;
use tallfat::backend::native::NativeBackend;
use tallfat::io::dataset::{gen_exact, Spectrum};
use tallfat::io::InputSpec;
use tallfat::linalg::Matrix;
use tallfat::svd::Svd;
use tallfat::update::Update;

const M0: usize = 6000;
const N: usize = 48;
const K: usize = 16;
const FRACTIONS: &[f64] = &[0.05, 0.25, 0.5, 1.0];

fn write_rows(a: &Matrix, r0: usize, r1: usize, path: &std::path::Path) -> InputSpec {
    let spec = InputSpec::csv(path.to_string_lossy().into_owned());
    tallfat::io::write_matrix(&a.slice_rows(r0, r1), &spec).unwrap();
    spec
}

fn main() {
    let dir = common::bench_dir("update");
    let max_extra = (FRACTIONS.last().copied().unwrap() * M0 as f64) as usize;
    let (a, _) = gen_exact(
        M0 + max_extra,
        N,
        K,
        Spectrum::Geometric { scale: 10.0, decay: 0.8 },
        0.01,
        2013,
    )
    .unwrap();

    let base_spec = write_rows(&a, 0, M0, &dir.join("A0.csv"));
    let model_dir = dir.join("model");
    let _ = std::fs::remove_dir_all(&model_dir);
    let build = |input: &InputSpec, model: &std::path::Path, work: &str| {
        Svd::over(input)
            .unwrap()
            .rank(K)
            .oversample(8)
            .workers(4)
            .block(256)
            .seed(7)
            .work_dir(work)
            .backend(Arc::new(NativeBackend::new()))
            .save_model(model.to_string_lossy().into_owned())
            .run()
            .unwrap()
    };
    let (_, base_time) = common::time_once(|| {
        build(&base_spec, &model_dir, &dir.join("work_base").to_string_lossy())
    });
    common::header(&format!(
        "incremental update vs full refactorization ({M0}x{N} base, k={K}, base build {:.2}s)",
        base_time.as_secs_f64()
    ));
    println!(
        "{:>10} {:>10} {:>12} {:>12} {:>9}",
        "fraction", "new rows", "update(s)", "full(s)", "speedup"
    );

    let mut points = Vec::new();
    for (i, &f) in FRACTIONS.iter().enumerate() {
        let extra = (f * M0 as f64) as usize;
        let batch = write_rows(&a, M0, M0 + extra, &dir.join(format!("batch_{i}.csv")));
        let concat = write_rows(&a, 0, M0 + extra, &dir.join(format!("concat_{i}.csv")));

        // Update the *base* model each time (fresh copy so every point
        // appends to the same parent).
        let upd_model = dir.join(format!("model_upd_{i}"));
        let _ = std::fs::remove_dir_all(&upd_model);
        copy_dir(&model_dir, &upd_model);
        let work_u = dir.join(format!("work_upd_{i}")).to_string_lossy().into_owned();
        let (res, t_update) = common::time_once(|| {
            Update::of(&upd_model)
                .unwrap()
                .rows(&batch)
                .oversample(8)
                .workers(4)
                .block(256)
                .seed(9)
                .work_dir(&work_u)
                .backend(Arc::new(NativeBackend::new()))
                .run()
                .unwrap()
        });
        assert_eq!(res.m, M0 + extra);

        let full_model = dir.join(format!("model_full_{i}"));
        let _ = std::fs::remove_dir_all(&full_model);
        let work_f = dir.join(format!("work_full_{i}")).to_string_lossy().into_owned();
        let (_, t_full) = common::time_once(|| build(&concat, &full_model, &work_f));

        let speedup = t_full.as_secs_f64() / t_update.as_secs_f64().max(1e-9);
        println!(
            "{:>10.2} {:>10} {:>12.4} {:>12.4} {:>8.2}x",
            f,
            extra,
            t_update.as_secs_f64(),
            t_full.as_secs_f64(),
            speedup
        );
        points.push(format!(
            "{{\"fraction\":{f},\"rows_added\":{extra},\"update_s\":{:.6},\"full_s\":{:.6},\"speedup\":{:.4}}}",
            t_update.as_secs_f64(),
            t_full.as_secs_f64(),
            speedup
        ));
    }

    let json = format!(
        "{{\"bench\":\"update\",\"m0\":{M0},\"n\":{N},\"k\":{K},\"base_build_s\":{:.6},\"points\":[{}]}}\n",
        base_time.as_secs_f64(),
        points.join(",")
    );
    common::write_json("update", &json);
}

/// Recursive copy (the bench clones the base model per data point).
fn copy_dir(src: &std::path::Path, dst: &std::path::Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        let to = dst.join(entry.file_name());
        if entry.file_type().unwrap().is_dir() {
            copy_dir(&entry.path(), &to);
        } else {
            std::fs::copy(entry.path(), &to).unwrap();
        }
    }
}
